//! `paper` — regenerates every table and figure in the paper's
//! evaluation from this reproduction (DESIGN.md §4 experiment index).
//!
//! Each subcommand runs the corresponding workload, writes a CSV under
//! `results/`, and prints the paper-shaped rows. `paper all` runs the
//! full set at the default (CPU-budget) scales; flags raise the scale:
//!
//!   paper fig2 --sizes tiny,small,med --steps 100 --seeds 4
//!   paper table5 --steps 40
//!   paper all
//!
//! Absolute numbers come from this testbed (CPU PJRT, model zoo); the
//! *shape* of every result — who wins, by what factor, where crossovers
//! fall — is the reproduction target (see EXPERIMENTS.md).

use anyhow::Result;
use pulse::analysis;
use pulse::bf16::Dtype;
use pulse::codec::Codec;
use pulse::coordinator::metrics::{print_table, results_dir, CsvWriter};
use pulse::coordinator::{self, Method, TrainConfig};
use pulse::net::{self, SimLink};
use pulse::optim::AdamConfig;
use pulse::rl::grpo::GrpoConfig;
use pulse::runtime::{artifacts_dir, ModelRuntime};
use pulse::sparse::{self, PatchFormat};
use pulse::util::cli::Args;
use pulse::util::{fmt_bytes, mean, stddev, Stopwatch};

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let t0 = Stopwatch::start();
    let r = dispatch(cmd, &args);
    if let Err(e) = r {
        eprintln!("error in '{}': {:#}", cmd, e);
        std::process::exit(1);
    }
    eprintln!("[paper {}] done in {:.1}s", cmd, t0.secs());
}

fn dispatch(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "fig1" => fig1(args),
        "fig2" => fig2(args),
        "fig3" => fig3(args),
        "fig4" => fig4(args),
        "fig6" => fig6(args),
        "fig7" => fig7(args),
        "fig8" => fig8(args),
        "fig9" => fig9(args),
        "fig10" | "table4" => fig10_table4(args),
        "fig11" | "fig18" => fig11(args),
        "fig12" => fig12(args),
        "fig13" => fig13(args),
        "fig14" => fig14(args),
        "fig15" => fig15(args),
        "fig16" => fig16(args),
        "fig17" => fig17(args),
        "table1" => table1(args),
        "table2" => table2(args),
        "table5" | "table12" => table5(args),
        "table6" => table6(args),
        "table7" => table7(args),
        "table10" => table10(args),
        "table11" => table11(args),
        "table13" => table13(args),
        "table14" => table14(args),
        "transports" => transports(args),
        "cache" => cache(args),
        "topology" => topology(args),
        "control" => control(args),
        "scale" => scale(args),
        "benchguard" => benchguard(args),
        "lint" => lint(args),
        "obs" => obs_cmd(args),
        "trace" => trace(args),
        "all" => {
            for c in [
                "table1", "fig9", "fig3", "table2", "table6", "fig1", "fig2", "fig14", "fig13",
                "fig16", "fig15", "fig4", "fig8", "table5", "table10", "table11", "table13",
                "fig11", "table14", "transports", "cache", "topology", "control", "fig7",
                "fig10", "fig12", "fig17", "table7", "fig6",
            ] {
                println!("\n################ paper {} ################", c);
                dispatch(c, args)?;
            }
            Ok(())
        }
        _ => {
            println!(
                "usage: paper <exp> [--options]\n\
                 exps: fig1 fig2 fig3 fig4 fig6 fig7 fig8 fig9 fig10 fig11 fig12 fig13 fig14\n\
                 fig15 fig16 fig17 table1 table2 table4 table5 table6 table7 table10\n\
                 table11 table13 table14 transports cache topology control all\n\
                 gates: scale (sim scale gate) benchguard (bench regression guard)\n\
                 lint (static analysis: paper lint [--json results/lint.json])\n\
                 obs <host:port> [--events] (live OBS_SNAP snapshot from any sync-plane node)\n\
                 trace [--sim] (flight-recorder timeline reconstruction -> results/trace.csv)"
            );
            Ok(())
        }
    }
}

fn load(size: &str) -> Result<ModelRuntime> {
    // only the graphs the harness executes (compiling gate/adam too
    // roughly doubles load time)
    ModelRuntime::load(&artifacts_dir(), size, &["rollout", "grad", "score"])
}

/// Manifest + init only (no graph compilation) — for weight-stats
/// tables.
fn load_weights(size: &str) -> Result<Vec<f32>> {
    let m = pulse::runtime::ModelManifest::load(
        &artifacts_dir().join(format!("{}.meta.json", size)),
    )?;
    let name = m.init.ok_or_else(|| anyhow::anyhow!("no init.bin for {}", size))?;
    Ok(pulse::util::bytes_to_f32(&std::fs::read(artifacts_dir().join(name))?))
}

fn sizes_arg(args: &Args, default: &str) -> Vec<String> {
    args.str_or("sizes", default).split(',').map(|s| s.trim().to_string()).collect()
}

/// Shared single-trainer run used by several figures.
fn run_single(
    size: &str,
    steps: usize,
    seed: u64,
    lr: f32,
    s_interval: usize,
    capture_every: usize,
    eval_every: usize,
) -> Result<coordinator::TrainResult> {
    let rt = load(size)?;
    let cfg = TrainConfig {
        steps,
        seed,
        rollout_interval: s_interval,
        adam: AdamConfig { lr, ..Default::default() },
        grpo: GrpoConfig::default(),
        eval_every,
        n_eval: 64,
        sparsity_ks: vec![1, 2, 4, 8, 16, 32],
        capture_every,
        ..Default::default()
    };
    coordinator::train(&rt, &cfg)
}

// ================================================================ fig1
/// Compute utilization vs bandwidth for both channels (paper Fig. 1).
/// Payload sizes: measured patch/pseudo-gradient sparsity on this
/// testbed, scaled to the paper's 7B parameter count by byte
/// arithmetic; dense baselines are exact.
fn fig1(args: &Args) -> Result<()> {
    let steps = args.usize_or("steps", 10);
    // measure PULSESync patch fraction + PULSELoCo payload fraction on
    // the small model at paper learning rates
    let res = run_single("small", steps, 0, 3e-6, 1, 1, 0)?;
    let rt = load("small")?;
    let n_small = rt.manifest.n_params as f64;
    let mut patch_frac = Vec::new();
    for w in res.captures.windows(2) {
        // container bytes ≈ 3 bytes/index + 2 bytes/value after codec
        let (idx, vals) = sparse::diff_gather_bf16(&w[0].1, &w[1].1);
        let patch = pulse::sparse::container::Patch {
            step: 0,
            base_step: 0,
            total_params: n_small as u64,
            indices: idx,
            values: pulse::sparse::container::Values::Bf16(vals),
            result_hash: String::new(),
            chunk_elems: 0,
            ..Default::default()
        };
        let obj = pulse::sparse::container::encode(
            &patch,
            &rt.manifest.layout,
            Default::default(),
        )?;
        patch_frac.push(obj.len() as f64 / (n_small * 2.0));
    }
    let mean_patch_frac = mean(&patch_frac);

    const N7B: f64 = 7.0e9;
    let full_sync = N7B * 2.0; // 14 GB BF16
    let pulse_sync = full_sync * mean_patch_frac;
    let diloco = 7.62e9 * 4.0; // 30.5 GB FP32
    // PULSELoCo encoded payload: paper-measured 1.77 GB ≈ 5.8% of dense;
    // our measured LoCo fraction from fig10 runs lands nearby — use the
    // measured patch fraction as a proxy scale for the hero figure and
    // report both.
    let ploco = diloco / 17.2;

    let mut csv = CsvWriter::create(
        &results_dir().join("fig1_utilization.csv"),
        &["gbps", "full_sync", "pulse_sync", "diloco", "pulseloco"],
    )?;
    let compute_s = 50.0;
    println!("payloads: full 14 GB | PULSESync {} (measured frac {:.4}) | DiLoCo 30.5 GB | PULSELoCo {}",
        fmt_bytes(pulse_sync as u64), mean_patch_frac, fmt_bytes(ploco as u64));
    let mut rows = Vec::new();
    for exp in -4..=8 {
        let gbps = 2f64.powi(exp);
        let link = SimLink { bandwidth_bps: gbps * 1e9, latency_s: 0.0 };
        let u = |bytes: f64| net::utilization(compute_s, bytes as u64, link);
        csv.rowf(&[gbps, u(full_sync), u(pulse_sync), u(diloco), u(ploco)])?;
        rows.push(vec![
            format!("{:.4}", gbps),
            format!("{:.3}", u(full_sync)),
            format!("{:.3}", u(pulse_sync)),
            format!("{:.3}", u(diloco)),
            format!("{:.3}", u(ploco)),
        ]);
    }
    print_table(
        "Fig 1: utilization vs bandwidth (7B, 50s compute interval)",
        &["Gbit/s", "full-ckpt", "PULSESync", "DiLoCo", "PULSELoCo"],
        &rows,
    );
    // the paper's 90% thresholds
    let thr = |bytes: f64| net::bandwidth_for_utilization(compute_s, bytes as u64, 0.9) / 1e9;
    println!(
        "90% thresholds (Gbit/s): full {:.1} | PULSESync {:.2} | DiLoCo {:.1} | PULSELoCo {:.2}",
        thr(full_sync),
        thr(pulse_sync),
        thr(diloco),
        thr(ploco)
    );
    println!("paper:                   full ~20 | PULSESync ~0.2 | DiLoCo ~44  | PULSELoCo ~2.6");
    Ok(())
}

// ================================================================ fig2
/// Weight-update sparsity across the model zoo (paper Fig. 2a/b).
fn fig2(args: &Args) -> Result<()> {
    let sizes = sizes_arg(args, "tiny,small");
    let steps = args.usize_or("steps", 24);
    let seeds = args.usize_or("seeds", 2);
    let mut csv = CsvWriter::create(
        &results_dir().join("fig2_sparsity.csv"),
        &["size", "seed", "k", "mean_sparsity", "std_sparsity"],
    )?;
    let mut rows = Vec::new();
    for size in &sizes {
        let mut per_k: std::collections::BTreeMap<usize, Vec<f64>> = Default::default();
        for seed in 0..seeds as u64 {
            let res = run_single(size, steps, seed, 3e-6, 1, 0, 0)?;
            let mut by_k: std::collections::BTreeMap<usize, Vec<f64>> = Default::default();
            for s in &res.steps {
                // skip the warmup transient for the headline mean (the
                // paper averages the full 400 steps; our short runs
                // weight warmup too heavily otherwise — fig16 shows it)
                if s.step <= 4 {
                    continue;
                }
                for &(k, v) in &s.sparsity {
                    by_k.entry(k).or_default().push(v);
                }
            }
            for (k, vs) in by_k {
                csv.row(&[
                    size.clone(),
                    seed.to_string(),
                    k.to_string(),
                    format!("{}", mean(&vs)),
                    format!("{}", stddev(&vs)),
                ])?;
                per_k.entry(k).or_default().extend(vs);
            }
        }
        let s1 = per_k.get(&1).map(|v| mean(v)).unwrap_or(f64::NAN);
        let s1sd = per_k.get(&1).map(|v| stddev(v)).unwrap_or(f64::NAN);
        let s8 = per_k.get(&8).map(|v| mean(v)).unwrap_or(f64::NAN);
        let s32 = per_k.get(&32).map(|v| mean(v)).unwrap_or(f64::NAN);
        rows.push(vec![
            size.clone(),
            format!("{:.4} ± {:.4}", s1, s1sd),
            format!("{:.4}", s8),
            format!("{:.4}", s32),
        ]);
    }
    print_table(
        "Fig 2: per-step (k=1) and k-step sparsity (paper: ~0.99 at k=1, >0.98 at k<=8)",
        &["model", "S1 (mean±sd)", "S8", "S32"],
        &rows,
    );
    Ok(())
}

// ================================================================ fig3
/// BF16 absorption geometry (paper Fig. 3b): weight magnitudes vs the
/// visibility threshold and the Adam bounds.
fn fig3(args: &Args) -> Result<()> {
    let flat = load_weights(&args.str_or("size", "med"))?;
    let eta = 3e-6f64;
    let mut csv = CsvWriter::create(
        &results_dir().join("fig3_absorption.csv"),
        &["w_abs", "threshold", "effective_bound", "absorption_bound"],
    )?;
    let mut rng = pulse::util::rng::Rng::new(1);
    let mut below_eff = 0usize;
    let mut below_abs = 0usize;
    let samples = 4000;
    for _ in 0..samples {
        let w = flat[rng.below(flat.len() as u64) as usize].abs() as f64;
        let thr = w / 256.0;
        csv.rowf(&[w, thr, eta, 10.0 * eta])?;
        if eta < thr {
            below_eff += 1;
        }
        if 10.0 * eta < thr {
            below_abs += 1;
        }
    }
    println!(
        "Fig 3b: {:.1}% of sampled weights have effective bound η below threshold;\n\
         {:.1}% have even the 10η absorption bound below threshold\n\
         (paper: 'most lie to the right of the absorption-bound crossing';\n\
         magnitude argument alone predicts 95–98% one-step absorption)",
        100.0 * below_eff as f64 / samples as f64,
        100.0 * below_abs as f64 / samples as f64
    );
    Ok(())
}

// ================================================================ fig4
/// Policy staleness: sparsity vs rollout interval S (paper Fig. 4).
fn fig4(args: &Args) -> Result<()> {
    let steps = args.usize_or("steps", 20);
    let svals = args.usize_list_or("svals", &[1, 2, 4, 8, 16, 32]);
    let mut csv = CsvWriter::create(
        &results_dir().join("fig4_staleness.csv"),
        &["s_interval", "k", "mean_sparsity"],
    )?;
    let mut rows = Vec::new();
    for &s_int in &svals {
        let res = run_single(&args.str_or("size", "tiny"), steps, 0, 3e-6, s_int, 0, 0)?;
        let mut by_k: std::collections::BTreeMap<usize, Vec<f64>> = Default::default();
        for s in res.steps.iter().filter(|s| s.step > 4) {
            for &(k, v) in &s.sparsity {
                by_k.entry(k).or_default().push(v);
            }
        }
        let mut row = vec![format!("S={}", s_int)];
        for (k, vs) in &by_k {
            csv.rowf(&[s_int as f64, *k as f64, mean(vs)])?;
            if [1usize, 8, 32].contains(k) {
                row.push(format!("{:.4}", mean(vs)));
            }
        }
        rows.push(row);
    }
    print_table(
        "Fig 4: staleness (paper: S1 > 0.985 at S=32; all k > 0.975)",
        &["interval", "S1", "S8", "S32"],
        &rows,
    );
    Ok(())
}

// ================================================================ fig6
/// grail deployment: pass@1 + upload sizes per window (paper Fig. 6).
fn fig6(args: &Args) -> Result<()> {
    let rt = load(&args.str_or("size", "tiny"))?;
    let task = pulse::rl::tasks::MathTask::default();
    let windows = args.usize_or("windows", 5);
    let seeds = args.usize_or("seeds", 2);
    let mut csv = CsvWriter::create(
        &results_dir().join("fig6_grail.csv"),
        &["seed", "window", "pass1", "upload_bytes", "reduction"],
    )?;
    let mut rows = Vec::new();
    for seed in 0..seeds as u64 {
        let master = coordinator::init_master(&rt, seed)?;
        let mut sim = pulse::grail::GrailSim::new(
            &rt,
            &task,
            pulse::grail::GrailConfig {
                steps_per_window: args.usize_or("steps-per-window", 4),
                ..Default::default()
            },
            master,
            AdamConfig::post_training(),
            seed,
        )?;
        for w in 0..windows as u64 {
            let st = sim.run_window(w)?;
            let red = st.full_checkpoint_bytes as f64 / st.upload_bytes.max(1) as f64;
            csv.rowf(&[seed as f64, w as f64, st.pass_at_1, st.upload_bytes as f64, red])?;
            rows.push(vec![
                seed.to_string(),
                w.to_string(),
                format!("{:.3}", st.pass_at_1),
                fmt_bytes(st.upload_bytes),
                format!("{:.0}x", red),
            ]);
        }
    }
    print_table(
        "Fig 6: grail — pass@1 rises, uploads stay sparse (paper: >100x reduction)",
        &["seed", "window", "pass@1", "upload", "reduction"],
        &rows,
    );
    Ok(())
}

// ================================================================ fig7
/// DDP vs DiLoCo vs PULSELoCo pass@1 (paper Fig. 7).
fn fig7(args: &Args) -> Result<()> {
    let sizes = sizes_arg(args, "tiny");
    let seeds = args.usize_or("seeds", 2);
    let steps = args.usize_or("steps", 32);
    let h = args.usize_or("local-steps", 8);
    let workers = args.usize_or("workers", 4);
    let mut csv = CsvWriter::create(
        &results_dir().join("fig7_methods.csv"),
        &[
            "size", "method", "seed", "round", "global_step", "reward", "pass1",
            "comm_sparsity", "raw_payload", "encoded_payload", "dense_payload", "ckpt_sparsity",
        ],
    )?;
    let mut summary = Vec::new();
    for size in &sizes {
        let rt = load(size)?;
        for method in [Method::Ddp, Method::DiLoCo, Method::PulseLoCo] {
            let mut finals = Vec::new();
            for seed in 0..seeds as u64 {
                let cfg = TrainConfig {
                    method,
                    workers,
                    local_steps: h,
                    steps,
                    seed,
                    adam: AdamConfig::post_training(),
                    eval_every: h * 2,
                    n_eval: 64,
                    ..Default::default()
                };
                let res = coordinator::train(&rt, &cfg)?;
                for r in &res.rounds {
                    let c = r.comm.first().cloned().unwrap_or_default();
                    csv.row(&[
                        size.clone(),
                        method.name().into(),
                        seed.to_string(),
                        r.round.to_string(),
                        r.global_step.to_string(),
                        format!("{}", r.mean_reward),
                        r.pass_at_1.map(|p| p.to_string()).unwrap_or_default(),
                        format!("{}", c.comm_sparsity),
                        c.raw_payload_bytes.to_string(),
                        c.encoded_payload_bytes.to_string(),
                        c.dense_bytes.to_string(),
                        format!("{}", r.ckpt_sparsity),
                    ])?;
                }
                finals.push(res.final_pass_at_1);
            }
            summary.push(vec![
                size.clone(),
                method.name().into(),
                format!("{:.3} ± {:.3}", mean(&finals), stddev(&finals)),
            ]);
        }
    }
    print_table(
        "Fig 7: final pass@1 by method (paper: PULSELoCo matches DiLoCo within seed variance)",
        &["model", "method", "final pass@1"],
        &summary,
    );
    Ok(())
}

// ================================================================ fig8
/// Mixed-precision training sparsity over steps (paper Fig. 8).
fn fig8(args: &Args) -> Result<()> {
    let steps = args.usize_or("steps", 30);
    let res = run_single(&args.str_or("size", "small"), steps, 0, 3e-6, 1, 0, 10)?;
    let mut csv = CsvWriter::create(
        &results_dir().join("fig8_mixed_precision.csv"),
        &["step", "s1", "reward", "pass1"],
    )?;
    let mut post_warmup = Vec::new();
    for s in &res.steps {
        let s1 = s.sparsity.iter().find(|(k, _)| *k == 1).map(|(_, v)| *v).unwrap_or(f64::NAN);
        csv.rowf(&[s.step as f64, s1, s.mean_reward, s.pass_at_1.unwrap_or(f64::NAN)])?;
        if s.step > 20 {
            post_warmup.push(s1);
        }
    }
    println!(
        "Fig 8: FP32-master + BF16-compute sparsity, post-warmup mean S1 = {:.4} (paper: >0.994)",
        mean(&post_warmup)
    );
    Ok(())
}

// ================================================================ fig9
/// Adversarial Adam ratio (paper Fig. 9).
fn fig9(_args: &Args) -> Result<()> {
    let trace = analysis::adversarial_rho(0.9, 0.999, 100_000, 3000);
    let mut csv =
        CsvWriter::create(&results_dir().join("fig9_rho.csv"), &["loud_step", "rho"])?;
    for (i, &r) in trace.iter().enumerate() {
        csv.rowf(&[(i + 1) as f64, r])?;
    }
    let (argmax, max) = trace
        .iter()
        .enumerate()
        .fold((0, 0.0), |(ai, am), (i, &x)| if x > am { (i, x) } else { (ai, am) });
    println!(
        "Fig 9: rho peaks at {:.2} after {} loud steps (paper: 6.57 after 12), bound 10;\n\
        decays to {:.3} by step 3000; constant-gradient rho = {:.3}",
        max,
        argmax + 1,
        trace[2999],
        {
            let mut t = analysis::RhoTrace::new(0.9, 0.999);
            let mut last = 0.0;
            for _ in 0..1000 {
                last = t.push(1.0);
            }
            last
        }
    );
    Ok(())
}

// ===================================================== fig10 + table4
/// PULSELoCo operating-point sparsity (Fig. 10) and communication
/// sparsity / FP32-value reduction (Table 4).
fn fig10_table4(args: &Args) -> Result<()> {
    let sizes = sizes_arg(args, "tiny,small");
    let steps = args.usize_or("steps", 24);
    let h = args.usize_or("local-steps", 8);
    let mut csv = CsvWriter::create(
        &results_dir().join("fig10_operating_points.csv"),
        &["size", "h", "round", "ckpt_sparsity", "comm_sparsity", "raw_payload", "dense"],
    )?;
    let mut rows = Vec::new();
    for size in &sizes {
        let rt = load(size)?;
        let cfg = TrainConfig {
            method: Method::PulseLoCo,
            workers: 4,
            local_steps: h,
            steps,
            adam: AdamConfig::post_training(),
            n_eval: 16,
            ..Default::default()
        };
        let res = coordinator::train(&rt, &cfg)?;
        let mut comm_sp = Vec::new();
        let mut ckpt_sp = Vec::new();
        for r in &res.rounds {
            for c in &r.comm {
                comm_sp.push(c.comm_sparsity);
                csv.rowf(&[
                    0.0,
                    h as f64,
                    r.round as f64,
                    r.ckpt_sparsity,
                    c.comm_sparsity,
                    c.raw_payload_bytes as f64,
                    c.dense_bytes as f64,
                ])?;
            }
            ckpt_sp.push(r.ckpt_sparsity);
        }
        let cs = mean(&comm_sp);
        let sent = 1.0 - cs;
        rows.push(vec![
            size.clone(),
            h.to_string(),
            format!("{:.3}", mean(&ckpt_sp)),
            format!("{:.3}", cs),
            format!("{:.1}%", sent * 100.0),
            format!("{:.1}x", 1.0 / sent.max(1e-9)),
        ]);
    }
    print_table(
        "Fig 10 / Table 4: PULSELoCo operating points (paper: 94.8–96.4% comm sparsity, 19–28x)",
        &["model", "H", "ckpt sparsity", "comm sparsity", "FP32 sent", "value reduction"],
        &rows,
    );
    Ok(())
}

// ================================================================ fig11
/// Bandwidth-aware codec selection (Fig. 11/18 + crossovers §H.4.5).
fn fig11(args: &Args) -> Result<()> {
    let stats = measure_codecs(args)?;
    let payload = stats.payload_bytes;
    let mut csv = CsvWriter::create(
        &results_dir().join("fig11_codec_selection.csv"),
        &["mbps", "snappy", "lz4", "zstd1", "zstd3", "gzip6", "winner"],
    )?;
    let mut crossings = Vec::new();
    let mut last_winner: Option<&'static str> = None;
    for i in 0..60 {
        let mbps = 1.0 * 1.25f64.powi(i); // 1 .. ~80k Mbit/s
        let link = SimLink::mbit(mbps);
        let mut best = ("", f64::INFINITY);
        let mut row = vec![mbps];
        for c in &stats.rows {
            let t = net::total_transfer_time(payload, c.ratio, c.enc_mbps, c.dec_mbps, link);
            row.push(t);
            if t < best.1 {
                best = (c.name, t);
            }
        }
        if let Some(lw) = last_winner {
            if lw != best.0 {
                crossings.push(format!("{} → {} near {:.0} Mbit/s", lw, best.0, mbps));
            }
        }
        last_winner = Some(best.0);
        let mut cells: Vec<String> = row.iter().map(|v| format!("{}", v)).collect();
        cells.push(best.0.to_string());
        csv.row(&cells)?;
    }
    println!(
        "Fig 11: regime crossovers for a {} payload: {:?}\n(paper: zstd-3 → zstd-1 near 14–15 Mbit/s; zstd-1 → lz4/snappy near 800 Mbit/s)",
        fmt_bytes(payload),
        crossings
    );
    Ok(())
}

// ================================================================ fig12
/// Compression-ratio curves for PULSELoCo payloads (paper Fig. 12).
fn fig12(args: &Args) -> Result<()> {
    let rt = load(&args.str_or("size", "small"))?;
    let cfg = TrainConfig {
        method: Method::PulseLoCo,
        workers: 4,
        local_steps: args.usize_or("local-steps", 8),
        steps: args.usize_or("steps", 32),
        adam: AdamConfig::post_training(),
        n_eval: 16,
        ..Default::default()
    };
    let res = coordinator::train(&rt, &cfg)?;
    let mut csv = CsvWriter::create(
        &results_dir().join("fig12_loco_compression.csv"),
        &["round", "ratio_varint", "ratio_zstd1", "ratio_shuffle_zstd3"],
    )?;
    let mut rows = Vec::new();
    for r in &res.rounds {
        let c = &r.comm[0];
        let d = c.dense_bytes as f64;
        let row = [
            r.round as f64,
            d / c.raw_payload_bytes.max(1) as f64,
            d / c.encoded_payload_bytes.max(1) as f64,
            d / c.shuffled_zstd3_bytes.max(1) as f64,
        ];
        csv.rowf(&row)?;
        rows.push(vec![
            r.round.to_string(),
            format!("{:.1}x", row[1]),
            format!("{:.1}x", row[2]),
            format!("{:.1}x", row[3]),
        ]);
    }
    print_table(
        "Fig 12: PULSELoCo payload compression vs dense (paper 7B: 12.8x / 17.2x / 17.5x)",
        &["round", "delta-varint", "+zstd-1", "+shuffle+zstd-3"],
        &rows,
    );
    Ok(())
}

// ================================================================ fig13
/// Gradient density (paper Fig. 13): dense across models and LRs.
fn fig13(args: &Args) -> Result<()> {
    let sizes = sizes_arg(args, "tiny,small");
    let lrs = args.f64_list_or("lrs", &[1e-6, 3e-6, 1e-5]);
    let steps = args.usize_or("steps", 10);
    let mut csv = CsvWriter::create(
        &results_dir().join("fig13_grad_density.csv"),
        &["size", "lr", "step", "grad_density"],
    )?;
    let mut rows = Vec::new();
    for size in &sizes {
        for &lr in &lrs {
            let res = run_single(size, steps, 0, lr as f32, 1, 0, 0)?;
            let dens: Vec<f64> =
                res.steps.iter().map(|s| s.grad_density).filter(|&d| d > 0.0).collect();
            for s in &res.steps {
                csv.rowf(&[0.0, lr, s.step as f64, s.grad_density])?;
            }
            rows.push(vec![
                size.clone(),
                format!("{:.0e}", lr),
                format!("{:.4}", mean(&dens)),
            ]);
        }
    }
    print_table(
        "Fig 13: gradient density on active steps (paper: ~99% non-zero everywhere)",
        &["model", "lr", "mean grad density"],
        &rows,
    );
    Ok(())
}

// ================================================================ fig14
/// Training curves across scales (paper Fig. 14).
fn fig14(args: &Args) -> Result<()> {
    let sizes = sizes_arg(args, "tiny,small");
    let steps = args.usize_or("steps", 40);
    let mut csv = CsvWriter::create(
        &results_dir().join("fig14_training_curves.csv"),
        &["size", "step", "reward", "pass1"],
    )?;
    let mut rows = Vec::new();
    for size in &sizes {
        let res = run_single(size, steps, 0, 3e-6, 1, 0, 10)?;
        let mut first = f64::NAN;
        let mut last = f64::NAN;
        for s in &res.steps {
            if let Some(p) = s.pass_at_1 {
                if first.is_nan() {
                    first = p;
                }
                last = p;
            }
            csv.rowf(&[
                0.0,
                s.step as f64,
                s.mean_reward,
                s.pass_at_1.unwrap_or(f64::NAN),
            ])?;
        }
        rows.push(vec![
            size.clone(),
            format!("{:.3}", first),
            format!("{:.3}", last),
            format!("{:.3}", res.final_pass_at_1),
        ]);
    }
    print_table(
        "Fig 14: pass@1 over training (paper: rapid improvement then plateau)",
        &["model", "early pass@1", "late pass@1", "final"],
        &rows,
    );
    Ok(())
}

// ================================================================ fig15
/// Learning-rate effect on sparsity (paper Fig. 15).
fn fig15(args: &Args) -> Result<()> {
    let lrs = args.f64_list_or("lrs", &[1e-6, 3e-6, 1e-5, 3e-5, 1e-4]);
    let steps = args.usize_or("steps", 16);
    let mut csv = CsvWriter::create(
        &results_dir().join("fig15_lr_sweep.csv"),
        &["lr", "k", "mean_sparsity"],
    )?;
    let mut rows = Vec::new();
    for &lr in &lrs {
        let res = run_single(&args.str_or("size", "tiny"), steps, 0, lr as f32, 1, 0, 0)?;
        let mut by_k: std::collections::BTreeMap<usize, Vec<f64>> = Default::default();
        for s in res.steps.iter().filter(|s| s.step > 4) {
            for &(k, v) in &s.sparsity {
                by_k.entry(k).or_default().push(v);
            }
        }
        let mut row = vec![format!("{:.0e}", lr)];
        for k in [1usize, 8] {
            let m = by_k.get(&k).map(|v| mean(v)).unwrap_or(f64::NAN);
            csv.rowf(&[lr, k as f64, m])?;
            row.push(format!("{:.4}", m));
        }
        rows.push(row);
    }
    print_table(
        "Fig 15: higher LR → lower sparsity (paper: stable-RL range stays high-sparsity)",
        &["lr", "S1", "S8"],
        &rows,
    );
    Ok(())
}

// ================================================================ fig16
/// Warmup sparsity dip (paper Fig. 16).
fn fig16(args: &Args) -> Result<()> {
    let steps = args.usize_or("steps", 36);
    let res = run_single(&args.str_or("size", "tiny"), steps, 0, 3e-6, 1, 0, 0)?;
    let mut csv = CsvWriter::create(
        &results_dir().join("fig16_warmup.csv"),
        &["step", "lr", "s1", "s8"],
    )?;
    let mut min_s1 = (0u64, 1.0f64);
    for s in &res.steps {
        let g = |k: usize| s.sparsity.iter().find(|(kk, _)| *kk == k).map(|(_, v)| *v);
        let s1 = g(1).unwrap_or(f64::NAN);
        csv.rowf(&[s.step as f64, s.lr, s1, g(8).unwrap_or(f64::NAN)])?;
        if s1 < min_s1.1 {
            min_s1 = (s.step, s1);
        }
    }
    println!(
        "Fig 16: sparsity dips to {:.4} at step {} (warmup ends at step 20), recovers after\n\
         (paper: dip during warmup, minimum ≈ steps 15–25, never below ~0.97)",
        min_s1.1, min_s1.0
    );
    Ok(())
}

// ================================================================ fig17
/// H-ablation for PULSELoCo (paper Fig. 17).
fn fig17(args: &Args) -> Result<()> {
    let hs = args.usize_list_or("hs", &[4, 8, 16]);
    let rounds = args.usize_or("rounds", 3);
    let rt = load(&args.str_or("size", "tiny"))?;
    let mut csv = CsvWriter::create(
        &results_dir().join("fig17_h_ablation.csv"),
        &["h", "round", "ckpt_sparsity", "comm_sparsity"],
    )?;
    let mut rows = Vec::new();
    for &h in &hs {
        let cfg = TrainConfig {
            method: Method::PulseLoCo,
            workers: 4,
            local_steps: h,
            steps: h * rounds,
            adam: AdamConfig::post_training(),
            n_eval: 16,
            ..Default::default()
        };
        let res = coordinator::train(&rt, &cfg)?;
        let mut ckpt = Vec::new();
        let mut comm = Vec::new();
        for r in &res.rounds {
            ckpt.push(r.ckpt_sparsity);
            for c in &r.comm {
                comm.push(c.comm_sparsity);
            }
            csv.rowf(&[h as f64, r.round as f64, r.ckpt_sparsity, r.comm[0].comm_sparsity])?;
        }
        rows.push(vec![
            h.to_string(),
            format!("{:.4}", mean(&ckpt)),
            format!("{:.4}", mean(&comm)),
        ]);
    }
    print_table(
        "Fig 17: larger H → modestly lower sparsity (paper: 97.1% → 95.6% from H=4 to 16)",
        &["H", "ckpt sparsity", "comm sparsity"],
        &rows,
    );
    Ok(())
}

// ================================================================ table1
fn table1(_args: &Args) -> Result<()> {
    let mut rows = Vec::new();
    for (name, b1, b2) in [
        ("PyTorch default", 0.9, 0.999),
        ("LLaMA 2/3", 0.9, 0.95),
        ("DeepSeek-V3/R1", 0.9, 0.95),
        ("Qwen 2.5", 0.9, 0.95),
        ("OLMo 2", 0.9, 0.95),
    ] {
        let cfg = AdamConfig { beta1: b1, beta2: b2, lr: 1.0, ..Default::default() };
        rows.push(vec![
            name.to_string(),
            format!("{}", b1),
            format!("{}", b2),
            format!("{:.2}η", cfg.update_bound()),
            format!("{:.2}η", cfg.cauchy_supremum()),
        ]);
    }
    print_table(
        "Table 1: Adam asymptotic bounds (paper: 10η and √2η≈1.41η; Cauchy 7.27 / 1.16)",
        &["pipeline", "β1", "β2", "bound", "Cauchy supremum"],
        &rows,
    );
    Ok(())
}

// ================================================================ table2
fn table2(args: &Args) -> Result<()> {
    let sizes = sizes_arg(args, "tiny,small,med");
    let eta = 3e-6;
    let crit = analysis::critical_weight(eta, Dtype::Bf16);
    let mut csv = CsvWriter::create(
        &results_dir().join("table2_weight_stats.csv"),
        &["size", "median", "mean", "p5", "p95", "frac_above_crit"],
    )?;
    let mut rows = Vec::new();
    for size in &sizes {
        let flat = load_weights(size)?;
        let st = analysis::weight_stats(&flat, crit);
        csv.rowf(&[0.0, st.median, st.mean, st.p5, st.p95, st.frac_above_crit])?;
        rows.push(vec![
            size.clone(),
            format!("{:.4}", st.median),
            format!("{:.4}", st.mean),
            format!("{:.4}", st.p5),
            format!("{:.4}", st.p95),
            format!("{:.1}%", 100.0 * st.frac_above_crit),
        ]);
    }
    print_table(
        &format!(
            "Table 2: weight magnitudes vs |w|_crit = {:.1e} (paper: medians 0.010–0.018, 94.8–97.6% above)",
            crit
        ),
        &["model", "median |w|", "mean |w|", "5th %ile", "95th %ile", "% > crit"],
        &rows,
    );
    Ok(())
}

// ============================================== codec measurement core
struct CodecRow {
    name: &'static str,
    ratio: f64,
    full_ratio: f64,
    enc_mbps: f64,
    dec_mbps: f64,
}

struct CodecStats {
    rows: Vec<CodecRow>,
    payload_bytes: u64,
}

/// Build realistic patch payloads from a short training run and measure
/// every codec (ratio vs the COO stream, throughput on this CPU).
fn measure_codecs(args: &Args) -> Result<CodecStats> {
    let size = args.str_or("size", "small");
    let steps = args.usize_or("steps", 12);
    let rt = load(&size)?;
    let res = run_single(&size, steps, 0, 3e-6, 1, 1, 0)?;
    // pre-codec delta_coo_downscaled streams between consecutive ckpts
    let mut payloads = Vec::new();
    let mut dense_bytes = 0u64;
    for w in res.captures.windows(2) {
        let (idx, vals) = sparse::diff_gather_bf16(&w[0].1, &w[1].1);
        if idx.is_empty() {
            continue;
        }
        let mut raw = PatchFormat::CooDownscaled.encode_indices(&idx, &rt.manifest.layout);
        raw.extend_from_slice(pulse::util::u16_as_bytes(&vals));
        dense_bytes += (w[1].1.len() * 2) as u64;
        payloads.push(raw);
    }
    anyhow::ensure!(!payloads.is_empty(), "no non-empty patches captured");
    let total_raw: u64 = payloads.iter().map(|p| p.len() as u64).sum();
    let mut rows = Vec::new();
    for codec in Codec::ALL {
        let mut comp_total = 0u64;
        // throughput: time repeated encode/decode over all payloads
        let reps = 3usize;
        let t_enc = Stopwatch::start();
        for _ in 0..reps {
            comp_total = 0;
            for p in &payloads {
                comp_total += codec.compress(p)?.len() as u64;
            }
        }
        let enc_secs = t_enc.secs() / reps as f64;
        let compressed: Vec<Vec<u8>> =
            payloads.iter().map(|p| codec.compress(p).unwrap()).collect();
        let t_dec = Stopwatch::start();
        for _ in 0..reps {
            for (c, p) in compressed.iter().zip(&payloads) {
                let d = codec.decompress(c, p.len())?;
                debug_assert_eq!(d.len(), p.len());
            }
        }
        let dec_secs = t_dec.secs() / reps as f64;
        rows.push(CodecRow {
            name: codec.name(),
            ratio: total_raw as f64 / comp_total as f64,
            full_ratio: dense_bytes as f64 / comp_total as f64,
            enc_mbps: total_raw as f64 / 1e6 / enc_secs,
            dec_mbps: total_raw as f64 / 1e6 / dec_secs,
        });
    }
    Ok(CodecStats { rows, payload_bytes: total_raw / payloads.len() as u64 })
}

// ================================================================ table5
fn table5(args: &Args) -> Result<()> {
    let stats = measure_codecs(args)?;
    let mut csv = CsvWriter::create(
        &results_dir().join("table5_codecs.csv"),
        &["codec", "sparse_ratio", "full_ratio", "enc_mbps", "dec_mbps"],
    )?;
    let mut rows = Vec::new();
    for c in &stats.rows {
        csv.row(&[
            c.name.into(),
            format!("{}", c.ratio),
            format!("{}", c.full_ratio),
            format!("{}", c.enc_mbps),
            format!("{}", c.dec_mbps),
        ])?;
        rows.push(vec![
            c.name.to_string(),
            format!("{:.2}x", c.ratio),
            format!("{:.0}x", c.full_ratio),
            format!("{:.0}", c.enc_mbps),
            format!("{:.0}", c.dec_mbps),
        ]);
    }
    print_table(
        "Table 5/12: codec comparison (paper shape: zstd ratio > lz4/snappy ratio; snappy/lz4 encode fastest; gzip-6 dominated)",
        &["codec", "sparse ratio", "full ratio", "enc MB/s", "dec MB/s"],
        &rows,
    );
    // Pareto check: gzip-6 dominated by zstd-1?
    let z1 = stats.rows.iter().find(|r| r.name == "zstd-1").unwrap();
    let gz = stats.rows.iter().find(|r| r.name == "gzip-6").unwrap();
    println!(
        "gzip-6 dominated: ratio {:.2} vs zstd-1 {:.2}, encode {:.0} vs {:.0} MB/s ({}x slower)",
        gz.ratio,
        z1.ratio,
        gz.enc_mbps,
        z1.enc_mbps,
        (z1.enc_mbps / gz.enc_mbps).round()
    );
    Ok(())
}

// ================================================================ table6
fn table6(args: &Args) -> Result<()> {
    let flat = load_weights(&args.str_or("size", "med"))?;
    let rows_data = analysis::lower_precision_projection(&flat, 3e-6);
    let mut csv = CsvWriter::create(
        &results_dir().join("table6_lowprec.csv"),
        &["format", "mantissa_bits", "tau", "crit", "frac_above"],
    )?;
    let mut rows = Vec::new();
    for r in &rows_data {
        csv.row(&[
            r.dtype.name().into(),
            r.mantissa_bits.to_string(),
            format!("{}", r.tau),
            format!("{}", r.crit),
            format!("{}", r.frac_above),
        ])?;
        rows.push(vec![
            r.dtype.name().to_string(),
            r.mantissa_bits.to_string(),
            format!("1/{}", (1.0 / r.tau) as u64),
            format!("{:.1e}", r.crit),
            format!("{:.2}%", 100.0 * r.frac_above),
        ]);
    }
    print_table(
        "Table 6: lower-precision projection (paper: BF16 97.6% → FP8 99.5% → MXFP4 99.8% above crit)",
        &["format", "mantissa", "tau", "|w|_crit", "frac above"],
        &rows,
    );
    Ok(())
}

// ================================================================ table7
fn table7(_args: &Args) -> Result<()> {
    // measured comm sparsity per (model,H) from short PULSELoCo runs,
    // byte accounting scaled to the paper's parameter counts (§F.3).
    let ops: [(&str, u64, usize); 3] =
        [("tiny→7B", 7_620_000_000, 8), ("small→3B", 3_090_000_000, 8), ("small→3B", 3_090_000_000, 4)];
    let mut rows = Vec::new();
    let mut csv = CsvWriter::create(
        &results_dir().join("table7_bandwidth.csv"),
        &["op", "n", "h", "sparsity", "payload_gb", "reduction"],
    )?;
    for (i, (name, n, h)) in ops.iter().enumerate() {
        let size = if i == 0 { "tiny" } else { "small" };
        let rt = load(size)?;
        let cfg = TrainConfig {
            method: Method::PulseLoCo,
            workers: 4,
            local_steps: *h,
            steps: h * 2,
            adam: AdamConfig::post_training(),
            n_eval: 8,
            ..Default::default()
        };
        let res = coordinator::train(&rt, &cfg)?;
        let mut sp = Vec::new();
        for r in &res.rounds {
            for c in &r.comm {
                sp.push(c.comm_sparsity);
            }
        }
        // conservative rounding like the paper (§F.3)
        let sparsity = (mean(&sp) * 100.0).floor() / 100.0;
        let nnz = (*n as f64) * (1.0 - sparsity);
        let value_bytes = nnz * 4.0;
        // delta-varint index bytes: mean gap n/nnz → mostly 1-byte varints
        let index_bytes = nnz * (1.0 + ((*n as f64 / nnz).log2() / 7.0).floor().max(0.0));
        let payload = value_bytes + index_bytes;
        let dense = *n as f64 * 4.0;
        csv.rowf(&[i as f64, *n as f64, *h as f64, sparsity, payload / 1e9, dense / payload])?;
        rows.push(vec![
            name.to_string(),
            h.to_string(),
            format!("{:.3}", sparsity),
            fmt_bytes(payload as u64),
            format!("{:.1}x vs DiLoCo", dense / payload),
            format!("{:.0}x vs DDP", dense / payload * *h as f64),
        ]);
    }
    print_table(
        "Table 7: bandwidth reduction per operating point (paper: 12.8–26x vs DiLoCo; ×H vs DDP)",
        &["operating point", "H", "sparsity", "payload", "vs DiLoCo", "vs DDP"],
        &rows,
    );
    Ok(())
}

// ================================================================ table10
fn table10(args: &Args) -> Result<()> {
    let size = args.str_or("size", "small");
    let rt = load(&size)?;
    let res = run_single(&size, args.usize_or("steps", 10), 0, 3e-6, 1, 1, 0)?;
    let mut rows = Vec::new();
    let mut csv = CsvWriter::create(
        &results_dir().join("table10_components.csv"),
        &["config", "ratio_vs_raw_coo", "enc_mbps"],
    )?;
    // pipeline stages of §H.4.1
    let configs: [(&str, PatchFormat); 3] = [
        ("raw COO (baseline)", PatchFormat::CooRaw),
        ("+ delta encoding", PatchFormat::CooDelta),
        ("+ type downscaling", PatchFormat::CooDownscaled),
    ];
    let mut base_compressed = 0.0;
    for (name, fmt) in configs {
        let mut raw_total = 0u64;
        let mut comp_total = 0u64;
        let t = Stopwatch::start();
        for w in res.captures.windows(2) {
            let (idx, vals) = sparse::diff_gather_bf16(&w[0].1, &w[1].1);
            let mut raw = fmt.encode_indices(&idx, &rt.manifest.layout);
            raw.extend_from_slice(pulse::util::u16_as_bytes(&vals));
            raw_total += raw.len() as u64;
            comp_total += Codec::Zstd1.compress(&raw)?.len() as u64;
        }
        let secs = t.secs();
        if base_compressed == 0.0 {
            base_compressed = comp_total as f64;
        }
        let ratio = base_compressed / comp_total as f64;
        csv.row(&[
            name.into(),
            format!("{}", ratio),
            format!("{}", raw_total as f64 / 1e6 / secs),
        ])?;
        rows.push(vec![
            name.to_string(),
            format!("{:.3}x vs baseline", ratio),
            format!("{:+.1}%", 100.0 * (ratio - 1.0)),
        ]);
    }
    print_table(
        "Table 10: component contribution under zstd-1 (paper: +13.3% delta, +8.5% downscale, +22.9% total)",
        &["configuration", "compressed-size ratio", "improvement"],
        &rows,
    );
    Ok(())
}

// ================================================================ table11
fn table11(args: &Args) -> Result<()> {
    let size = args.str_or("size", "small");
    let rt = load(&size)?;
    let res = run_single(&size, args.usize_or("steps", 10), 0, 3e-6, 1, 1, 0)?;
    let mut rows = Vec::new();
    for (name, fmt) in [
        ("2D COO (delta_coo_int32)", PatchFormat::CooDelta),
        ("1D Flat (delta_flat_int32)", PatchFormat::FlatDelta),
        ("2D COO downscaled (default)", PatchFormat::CooDownscaled),
        ("1D Flat varint (LoCo wire)", PatchFormat::FlatVarint),
    ] {
        let mut raw_total = 0u64;
        let mut comp_total = 0u64;
        for w in res.captures.windows(2) {
            let (idx, vals) = sparse::diff_gather_bf16(&w[0].1, &w[1].1);
            let mut raw = fmt.encode_indices(&idx, &rt.manifest.layout);
            raw.extend_from_slice(pulse::util::u16_as_bytes(&vals));
            raw_total += raw.len() as u64;
            comp_total += Codec::Zstd1.compress(&raw)?.len() as u64;
        }
        rows.push(vec![
            name.to_string(),
            fmt_bytes(raw_total),
            fmt_bytes(comp_total),
            format!("{:.3}", raw_total as f64 / comp_total as f64),
        ]);
    }
    print_table(
        "Table 11: sparse representation formats (paper: flat beats COO at equal width; downscaled COO wins overall)",
        &["format", "raw", "zstd-1", "codec ratio"],
        &rows,
    );
    Ok(())
}

// ================================================================ table13
fn table13(args: &Args) -> Result<()> {
    let sizes = sizes_arg(args, "tiny,small");
    let mut rows = Vec::new();
    let mut csv = CsvWriter::create(
        &results_dir().join("table13_per_model.csv"),
        &["size", "sparsity", "full_ratio"],
    )?;
    for size in &sizes {
        let rt = load(size)?;
        let res = run_single(size, args.usize_or("steps", 10), 0, 3e-6, 1, 1, 0)?;
        let mut sp = Vec::new();
        let mut dense = 0u64;
        let mut comp = 0u64;
        for w in res.captures.windows(2) {
            let (idx, vals) = sparse::diff_gather_bf16(&w[0].1, &w[1].1);
            sp.push(sparse::sparsity(idx.len(), w[1].1.len()));
            let mut raw =
                PatchFormat::CooDownscaled.encode_indices(&idx, &rt.manifest.layout);
            raw.extend_from_slice(pulse::util::u16_as_bytes(&vals));
            dense += (w[1].1.len() * 2) as u64;
            comp += Codec::Zstd1.compress(&raw)?.len() as u64;
        }
        let full_ratio = dense as f64 / comp.max(1) as f64;
        csv.rowf(&[0.0, mean(&sp), full_ratio])?;
        rows.push(vec![
            size.clone(),
            format!("{:.3}", mean(&sp)),
            format!("{:.0}x", full_ratio),
        ]);
    }
    print_table(
        "Table 13: per-model compression with zstd-1 (paper: 76–100x, higher sparsity → higher ratio)",
        &["model", "sparsity", "full ratio"],
        &rows,
    );
    Ok(())
}

// ================================================================ table14
fn table14(args: &Args) -> Result<()> {
    // end-to-end latency at 400 Mb/s for a 7B model: measured codec
    // throughputs + the protocol's fast/slow/cold paths.
    let stats = measure_codecs(args)?;
    let z1 = stats.rows.iter().find(|r| r.name == "zstd-1").unwrap();
    let link = SimLink::mbit(400.0);
    const FULL: f64 = 14e9;
    const DELTA: f64 = 108e6; // paper's measured patch size at 7B
    let dl = |bytes: f64| link.transfer_time(bytes as u64);
    // processing throughputs measured on this CPU: verification is the
    // chunked hash tree (parallel build; incremental per patch), with
    // the serial full-buffer SHA-256 kept for comparison
    let sha_mbps = measure_sha_mbps();
    let tree_mbps = measure_tree_mbps();
    eprintln!(
        "verify throughput: scalar sha256 {:.0} MB/s → hash-tree {:.0} MB/s ({:.1}x)",
        sha_mbps,
        tree_mbps,
        tree_mbps / sha_mbps.max(1e-9)
    );
    let decomp = |bytes: f64| bytes / (z1.dec_mbps * 1e6);
    let apply_mbps = 2000.0; // memcpy-bound; see bench_patch
    let rows_def: [(&str, f64, f64, f64); 3] = [
        ("fast (1 delta)", 0.0, DELTA, 1.0),
        ("slow (anchor + 9 deltas)", FULL, DELTA * 9.0, 9.0),
        ("cold start (anchor)", FULL, 0.0, 0.0),
    ];
    let mut rows = Vec::new();
    let mut csv = CsvWriter::create(
        &results_dir().join("table14_latency.csv"),
        &["path", "download_s", "decompress_s", "apply_s", "hash_s", "total_s"],
    )?;
    for (name, full_b, delta_b, n_patches) in rows_def {
        let download = dl(full_b) + dl(delta_b);
        let dec = decomp(delta_b);
        let apply = delta_b / (apply_mbps * 1e6);
        let hash = (FULL * n_patches.max(1.0)) / (tree_mbps * 1e6);
        let total = download + dec + apply + hash;
        csv.row(&[
            name.into(),
            format!("{:.1}", download),
            format!("{:.2}", dec),
            format!("{:.2}", apply),
            format!("{:.2}", hash),
            format!("{:.1}", total),
        ])?;
        rows.push(vec![
            name.to_string(),
            format!("{:.1} s", download),
            format!("{:.2} s", dec),
            format!("{:.2} s", apply),
            format!("{:.2} s", hash),
            format!("{:.1} s", total),
        ]);
    }
    print_table(
        "Table 14: 7B sync latency at 400 Mb/s (paper: fast 3.9s, slow 315s, cold 281s)",
        &["path", "download", "decompress", "apply", "hash", "total"],
        &rows,
    );
    Ok(())
}

// ====================================================== transports
/// The same PULSESync stream over every local `SyncTransport` backend:
/// per-backend publish/synchronize wall time plus traffic counters
/// (`results/transport_plane.csv`). Object-store vs in-proc separates
/// store I/O from protocol cost; the fault-injected leg prices §J.5
/// self-healing (exactly one shard refetch for the injected
/// corruption).
fn transports(args: &Args) -> Result<()> {
    use pulse::coordinator::metrics::TransportMeter;
    use pulse::net::transport::{
        FaultInjectingTransport, InProcTransport, ObjectStoreTransport, SyncTransport,
    };
    use pulse::pulse::sync::{Consumer, Publisher};
    use pulse::storage::ObjectStore;
    use pulse::util::rng::Rng;

    fn drive<P: SyncTransport, C: SyncTransport>(
        prod: P,
        cons: C,
        layout: &[sparse::TensorShape],
        views: &[Vec<u16>],
        shards: usize,
        meter: &mut TransportMeter,
    ) -> Result<(String, f64, f64)> {
        let mut publisher =
            Publisher::over(prod, layout.to_vec(), views[0].clone(), 6)?.with_shards(shards);
        let mut consumer = Consumer::over(cons, layout.to_vec());
        consumer.synchronize()?;
        let label = consumer.transport.name().to_string();
        let (mut t_pub, mut t_sync) = (0.0f64, 0.0f64);
        for (step, view) in views.iter().enumerate().skip(1) {
            let t = Stopwatch::start();
            publisher.publish(step as u64, view)?;
            t_pub += t.secs();
            meter.record_publish(&label);
            let t = Stopwatch::start();
            let cs = consumer.synchronize()?;
            t_sync += t.secs();
            meter.record_sync(&label, &cs);
            anyhow::ensure!(
                cs.verified && consumer.weights.as_ref().unwrap() == view,
                "bit-identity broken on {} at step {}",
                label,
                step
            );
        }
        meter.set_counters(&label, consumer.transport.counters());
        Ok((label, t_pub, t_sync))
    }

    let n = args.usize_or("params", 400_000);
    let steps = args.usize_or("steps", 12) as u64;
    let shards = args.usize_or("shards", 4).max(1);
    let layout = sparse::synthetic_layout(n, 1024);
    let mut rng = Rng::new(41);
    let init: Vec<u16> = (0..n).map(|_| rng.next_u32() as u16).collect();
    let mut views = vec![init.clone()];
    {
        let mut w = init;
        for _ in 0..steps {
            for _ in 0..n / 100 {
                let i = rng.below(n as u64) as usize;
                w[i] = rng.next_u32() as u16;
            }
            views.push(w.clone());
        }
    }

    let mut meter = TransportMeter::new();
    let mut timings = Vec::new();
    let store = ObjectStore::temp("paper_transports")?;
    timings.push(drive(
        ObjectStoreTransport::new(store.clone(), "sync"),
        ObjectStoreTransport::new(store.clone(), "sync"),
        &layout,
        &views,
        shards,
        &mut meter,
    )?);
    let fabric = InProcTransport::new();
    timings.push(drive(fabric.clone(), fabric, &layout, &views, shards, &mut meter)?);
    if shards > 1 {
        // fault-injected in-proc: corrupt one shard of step 2 once; the
        // consumer must heal it with exactly one refetch
        let fabric = InProcTransport::new();
        let cons =
            FaultInjectingTransport::targeting(fabric.clone(), 2, 1.min(shards as u32 - 1));
        timings.push(drive(fabric, cons, &layout, &views, shards, &mut meter)?);
    } else {
        // unsharded streams never call fetch_shard, so the targeted
        // corruption scenario would silently measure nothing
        println!("(fault-injected leg skipped: needs --shards > 1)");
    }

    let results = results_dir();
    meter.write_csv(&results.join("transport_plane.csv"))?;
    let mut rows = Vec::new();
    for ((label, t_pub, t_sync), row) in timings.iter().zip(meter.rows()) {
        rows.push(vec![
            label.clone(),
            format!("{:.1} ms", t_pub * 1e3 / steps as f64),
            format!("{:.1} ms", t_sync * 1e3 / steps as f64),
            fmt_bytes(row.counters.bytes_published),
            fmt_bytes(row.counters.bytes_fetched),
            row.counters.inventory_scans.to_string(),
            row.shard_refetches.to_string(),
            row.counters.faults_injected.to_string(),
        ]);
    }
    print_table(
        &format!(
            "Transport plane: identical {}-step stream ({} params, {} shards) per backend",
            steps, n, shards
        ),
        &[
            "transport",
            "publish/step",
            "sync/step",
            "bytes up",
            "bytes down",
            "scans",
            "refetches",
            "faults",
        ],
        &rows,
    );
    std::fs::remove_dir_all(store.root()).ok();
    Ok(())
}

// ====================================================== store cache
/// Star vs 2-level cached tree over real store-plane sockets: the same
/// published stream, the same number of cold leaves, once with every
/// leaf pulling straight from the origin store server and once through
/// two `CachingStore` hops. The table prices what the caching tier
/// buys: origin egress bytes and the leaf-side hit rate
/// (`results/store_cache.csv`). Leaves sync sequentially — concurrent
/// cold misses on one hop can each reach the origin (no single-flight
/// dedup; see `net::store` module docs), and this table measures the
/// steady caching bound, not that race.
fn cache(args: &Args) -> Result<()> {
    use pulse::net::store::{caching_hop, DirectStore, RemoteStoreTransport, StoreServer};
    use pulse::net::transport::SyncTransport;
    use pulse::pulse::sync::{Consumer, Publisher};
    use pulse::storage::retention::RetentionPolicy;
    use pulse::storage::ObjectStore;
    use pulse::util::rng::Rng;
    use std::sync::atomic::Ordering;
    use std::sync::Arc;

    let n = args.usize_or("params", 200_000);
    let steps = args.usize_or("steps", 6) as u64;
    let shards = args.usize_or("shards", 4).max(1);
    // ≥ 4 so each of the two hops serves ≥ 2 leaves and the egress
    // assertion below is meaningful
    let leaves = args.usize_or("leaves", 6).max(4);
    let layout = sparse::synthetic_layout(n, 1024);
    let mut rng = Rng::new(47);
    let init: Vec<u16> = (0..n).map(|_| rng.next_u32() as u16).collect();
    let mut views = vec![init.clone()];
    {
        let mut w = init;
        for _ in 0..steps {
            for _ in 0..n / 100 {
                let i = rng.below(n as u64) as usize;
                w[i] = rng.next_u32() as u16;
            }
            views.push(w.clone());
        }
    }

    // one origin serves both legs; the stream is published once and
    // every leaf syncs the same cold workload from scratch
    let store = ObjectStore::temp("paper_cache")?;
    let origin = StoreServer::serve(Arc::new(DirectStore::new(store.clone())), None)?;
    let mut publisher = Publisher::over(
        RemoteStoreTransport::connect(origin.port(), "sync"),
        layout.clone(),
        views[0].clone(),
        6,
    )?
    .with_shards(shards);
    for (step, view) in views.iter().enumerate().skip(1) {
        publisher.publish(step as u64, view)?;
    }
    let final_view = views.last().unwrap();

    // sync a batch of cold leaves sequentially, aggregating the
    // store-plane counters: (hits, misses, origin_fetches)
    let run_leaves = |ports: Vec<u16>| -> Result<(u64, u64, u64)> {
        let (mut hits, mut misses, mut fetched) = (0u64, 0u64, 0u64);
        for p in ports {
            let mut c =
                Consumer::over(RemoteStoreTransport::connect(p, "sync"), layout.clone());
            let s = c.synchronize()?;
            anyhow::ensure!(
                s.verified && c.weights.as_ref().unwrap() == final_view,
                "bit-identity broken on the store plane"
            );
            let counters = c.transport.counters();
            hits += counters.cache_hits;
            misses += counters.cache_misses;
            fetched += counters.origin_fetches;
        }
        Ok((hits, misses, fetched))
    };
    let hit_rate = |h: u64, m: u64| 100.0 * h as f64 / (h + m).max(1) as f64;

    // star: every cold leaf pulls straight from the origin
    let star0 = origin.stats().bytes_served.load(Ordering::Relaxed);
    let (star_h, star_m, star_f) = run_leaves(vec![origin.port(); leaves])?;
    let star_bytes = origin.stats().bytes_served.load(Ordering::Relaxed) - star0;

    // 2-level cached tree: the same leaves split across two hops
    let (hop_a, cache_a) = caching_hop(origin.port(), RetentionPolicy::default(), None)?;
    let (hop_b, cache_b) = caching_hop(origin.port(), RetentionPolicy::default(), None)?;
    let tree0 = origin.stats().bytes_served.load(Ordering::Relaxed);
    let tree_ports: Vec<u16> = (0..leaves)
        .map(|i| if i % 2 == 0 { hop_a.port() } else { hop_b.port() })
        .collect();
    let (tree_h, tree_m, tree_f) = run_leaves(tree_ports)?;
    let tree_bytes = origin.stats().bytes_served.load(Ordering::Relaxed) - tree0;
    let tree_nm = cache_a.counters.not_modified.load(Ordering::Relaxed)
        + cache_b.counters.not_modified.load(Ordering::Relaxed);

    let results = results_dir();
    let mut w = CsvWriter::create(
        &results.join("store_cache.csv"),
        &[
            "topology",
            "leaves",
            "origin_bytes",
            "cache_hits",
            "cache_misses",
            "origin_fetches",
            "conditional_not_modified",
            "hit_rate_pct",
        ],
    )?;
    let mut rows = Vec::new();
    for (label, bytes, h, m, f, nm) in [
        ("store-star", star_bytes, star_h, star_m, star_f, 0u64),
        ("store-tree2", tree_bytes, tree_h, tree_m, tree_f, tree_nm),
    ] {
        w.row(&[
            label.to_string(),
            leaves.to_string(),
            bytes.to_string(),
            h.to_string(),
            m.to_string(),
            f.to_string(),
            nm.to_string(),
            format!("{:.1}", hit_rate(h, m)),
        ])?;
        rows.push(vec![
            label.to_string(),
            leaves.to_string(),
            fmt_bytes(bytes),
            h.to_string(),
            m.to_string(),
            f.to_string(),
            nm.to_string(),
            format!("{:.1}%", hit_rate(h, m)),
        ]);
    }
    print_table(
        &format!(
            "Store plane: origin egress for {} cold leaves, {}-step stream ({} params, {} shards)",
            leaves, steps, n, shards
        ),
        &[
            "topology",
            "leaves",
            "origin bytes",
            "hits",
            "misses",
            "origin fetches",
            "not-modified",
            "hit rate",
        ],
        &rows,
    );
    println!("  -> {}", results.join("store_cache.csv").display());
    anyhow::ensure!(
        tree_bytes < star_bytes,
        "caching hops must cut origin egress (tree {} vs star {})",
        tree_bytes,
        star_bytes
    );
    std::fs::remove_dir_all(store.root()).ok();
    Ok(())
}

// ====================================================== topology
/// Star vs 2-level relay tree for the same PULSESync stream and the
/// same number of leaf subscribers: per-hop `TransportMeter` rows
/// (`results/topology.csv`) plus publish / all-leaves-synced wall
/// times. The star saturates the root's uplink at high fan-out; the
/// tree pays one extra staging hop to halve the root's subscriber
/// count — this table is where that trade-off gets data points.
fn topology(args: &Args) -> Result<()> {
    use pulse::coordinator::metrics::TransportMeter;
    use pulse::net::node::RelayNode;
    use pulse::net::relay::Relay;
    use pulse::net::transport::{RelayTransport, SyncTransport};
    use pulse::pulse::sync::{Consumer, Publisher, SyncStats};
    use pulse::util::pool;
    use pulse::util::retry::Deadline;
    use pulse::util::rng::Rng;
    use std::sync::Arc;
    use std::time::Duration;

    /// Poll one leaf until `step` is committed from its view, then
    /// synchronize once (relays stage asynchronously).
    fn wait_sync(c: &mut Consumer<RelayTransport>, step: u64) -> Result<SyncStats> {
        let deadline = Deadline::after(Duration::from_secs(30));
        loop {
            if let Some(head) = c.latest_ready()? {
                if head >= step {
                    return c.synchronize();
                }
            }
            anyhow::ensure!(!deadline.expired(), "step {} never became ready", step);
            deadline.tick(Duration::from_millis(2));
        }
    }

    /// Drive the seeded stream from the root through `leaf_ports`;
    /// leaves synchronize in parallel (that IS the fan-out being
    /// measured). Returns (publish s/step, all-leaves-synced s/step).
    #[allow(clippy::too_many_arguments)]
    fn drive(
        label: &str,
        root: &Arc<Relay>,
        leaf_ports: &[u16],
        layout: &[sparse::TensorShape],
        views: &[Vec<u16>],
        shards: usize,
        meter: &mut TransportMeter,
    ) -> Result<(f64, f64)> {
        let root_label = format!("{}/root", label);
        let leaf_label = format!("{}/leaf", label);
        let mut publisher = Publisher::over(
            RelayTransport::publisher(root.clone()),
            layout.to_vec(),
            views[0].clone(),
            6,
        )?
        .with_shards(shards);
        let mut consumers: Vec<Consumer<RelayTransport>> = Vec::new();
        for &p in leaf_ports {
            consumers.push(Consumer::over(RelayTransport::subscribe(p)?, layout.to_vec()));
        }
        // cold start every leaf (slow path from anchor 0)
        let started = pool::par_map(consumers, |_, mut c| {
            let r = wait_sync(&mut c, 0);
            (c, r)
        });
        consumers = Vec::with_capacity(started.len());
        for (c, r) in started {
            r?;
            consumers.push(c);
        }
        let (mut t_pub, mut t_sync) = (0.0f64, 0.0f64);
        for (step, view) in views.iter().enumerate().skip(1) {
            let t = Stopwatch::start();
            publisher.publish(step as u64, view)?;
            t_pub += t.secs();
            meter.record_publish(&root_label);
            let t = Stopwatch::start();
            let synced = pool::par_map(consumers, |_, mut c| {
                let r = wait_sync(&mut c, step as u64);
                (c, r)
            });
            t_sync += t.secs();
            consumers = Vec::with_capacity(synced.len());
            for (c, r) in synced {
                let cs = r?;
                anyhow::ensure!(
                    cs.verified && c.weights.as_deref() == Some(view.as_slice()),
                    "bit-identity broken on {} at step {}",
                    label,
                    step
                );
                meter.record_sync(&leaf_label, &cs);
                consumers.push(c);
            }
        }
        let steps = (views.len() - 1).max(1) as f64;
        meter.set_hop(&root_label, 0);
        meter.set_hop(&leaf_label, consumers[0].transport.hops().unwrap_or(0));
        // one representative leaf's counters (they all carry the same
        // stream); the sync/refetch tallies above aggregate all leaves
        meter.set_counters(&leaf_label, consumers[0].transport.counters());
        Ok((t_pub / steps, t_sync / steps))
    }

    let n = args.usize_or("params", 200_000);
    let steps = args.usize_or("steps", 8) as u64;
    let shards = args.usize_or("shards", 4).max(1);
    let subs = args.usize_or("subs", 6).max(2);
    let layout = sparse::synthetic_layout(n, 1024);
    let mut rng = Rng::new(47);
    let init: Vec<u16> = (0..n).map(|_| rng.next_u32() as u16).collect();
    let mut views = vec![init.clone()];
    {
        let mut w = init;
        for _ in 0..steps {
            for _ in 0..n / 100 {
                let i = rng.below(n as u64) as usize;
                w[i] = rng.next_u32() as u16;
            }
            views.push(w.clone());
        }
    }

    let mut meter = TransportMeter::new();

    // star: every leaf subscribes to the root
    let root = Arc::new(Relay::start()?);
    let star_ports = vec![root.port; subs];
    let (star_pub, star_sync) =
        drive("star", &root, &star_ports, &layout, &views, shards, &mut meter)?;
    root.stop();

    // 2-level tree: two mid-tier nodes, leaves split across them —
    // the root now fans out to 2 subscribers instead of `subs`
    let root = Arc::new(Relay::start()?);
    let node_a = RelayNode::join(root.port)?;
    let node_b = RelayNode::join(root.port)?;
    // let the nodes learn their depth before leaves attach, so the
    // per-hop rows report hop 2 deterministically
    let deadline = Deadline::after(Duration::from_secs(5));
    while (node_a.hop() != 1 || node_b.hop() != 1) && !deadline.expired() {
        deadline.tick(Duration::from_millis(3));
    }
    let tree_ports: Vec<u16> =
        (0..subs).map(|i| if i % 2 == 0 { node_a.port() } else { node_b.port() }).collect();
    let (tree_pub, tree_sync) =
        drive("tree", &root, &tree_ports, &layout, &views, shards, &mut meter)?;
    let node_nacks = node_a.relay().nacks_serviced() + node_b.relay().nacks_serviced();
    node_a.stop();
    node_b.stop();
    root.stop();

    let results = results_dir();
    meter.write_csv(&results.join("topology.csv"))?;
    let mut rows = Vec::new();
    for r in meter.rows() {
        let (t_pub, t_sync) = if r.transport.starts_with("star") {
            (star_pub, star_sync)
        } else {
            (tree_pub, tree_sync)
        };
        rows.push(vec![
            r.transport.clone(),
            r.hop.to_string(),
            if r.publishes > 0 { format!("{:.1} ms", t_pub * 1e3) } else { String::new() },
            if r.syncs > 0 { format!("{:.1} ms", t_sync * 1e3) } else { String::new() },
            r.publishes.to_string(),
            r.syncs.to_string(),
            fmt_bytes(r.counters.bytes_fetched),
            r.shard_refetches.to_string(),
            r.slow_paths.to_string(),
        ]);
    }
    print_table(
        &format!(
            "Relay topology: star vs 2-level tree, {} leaves, {}-step stream \
             ({} params, {} shards; tree serviced {} NACKs mid-tier)",
            subs, steps, n, shards, node_nacks
        ),
        &[
            "role",
            "hop",
            "publish/step",
            "all-synced/step",
            "publishes",
            "syncs",
            "bytes down (1 leaf)",
            "refetches",
            "slow",
        ],
        &rows,
    );
    Ok(())
}

// ====================================================== control
/// Control-plane failover cost: replan latency and recovery traffic vs
/// subtree size. For each subtree size S the plane assembles 2 active
/// relays (S leaves each) + 1 standby from JOINs alone, streams a few
/// steps, then one active relay is crashed (silent heartbeats — only
/// the failure detector can see it). Reported per S: detection latency
/// (kill → epoch bump), recovery latency (kill → every orphaned leaf
/// verified at a post-kill step), and what the orphans paid to catch
/// up (re-parents, replayed anchors/patches, slow paths). Writes
/// `results/control_plane.csv`.
fn control(args: &Args) -> Result<()> {
    use pulse::coordinator::planner::Upstream;
    use pulse::net::control::{
        ControlConfig, ControlPlane, ControlSubscriberTransport, ControlledNode,
    };
    use pulse::net::relay::{Relay, DEFAULT_QUEUE_DEPTH, INDEX_STEPS};
    use pulse::net::transport::RelayTransport;
    use pulse::pulse::sync::{Consumer, Publisher, SyncPath, SyncStats};
    use pulse::util::pool;
    use pulse::util::retry::Deadline;
    use pulse::util::rng::Rng;
    use std::sync::Arc;
    use std::time::Duration;

    /// Poll one leaf until `step` is committed from its view, then
    /// synchronize; transient errors (mid-failover) retry.
    fn wait_sync(
        c: &mut Consumer<ControlSubscriberTransport>,
        step: u64,
    ) -> Result<SyncStats> {
        let deadline = Deadline::after(Duration::from_secs(30));
        loop {
            if let Ok(Some(head)) = c.latest_ready() {
                if head >= step {
                    if let Ok(cs) = c.synchronize() {
                        return Ok(cs);
                    }
                }
            }
            anyhow::ensure!(!deadline.expired(), "step {} never synced", step);
            deadline.tick(Duration::from_millis(3));
        }
    }

    let n = args.usize_or("params", 100_000);
    let pre_steps = args.usize_or("steps", 3) as u64;
    let subtrees = args.usize_list_or("subtrees", &[2, 4, 8]);
    let hb = Duration::from_millis(args.u64_or("heartbeat-ms", 50));
    let missed = args.usize_or("missed", 6) as u32;
    let layout = sparse::synthetic_layout(n, 1024);

    let results = results_dir();
    let mut csv = CsvWriter::create(
        &results.join("control_plane.csv"),
        &[
            "subtree",
            "leaves",
            "detect_ms",
            "recover_ms",
            "epoch",
            "reparents",
            "orphan_slow_paths",
            "catchup_patches",
            "catchup_anchors",
        ],
    )?;
    let mut rows = Vec::new();

    for &s in &subtrees {
        let s = s.max(2); // cap ≥ 2, so 2 relays need ≥ 2 leaves each
        let leaves_n = 2 * s;
        let mut rng = Rng::new(61 + s as u64);
        let init: Vec<u16> = (0..n).map(|_| rng.next_u32() as u16).collect();
        let mut views = vec![init.clone()];
        {
            let mut w = init;
            for _ in 0..pre_steps + 1 {
                for _ in 0..n / 100 {
                    let i = rng.below(n as u64) as usize;
                    w[i] = rng.next_u32() as u16;
                }
                views.push(w.clone());
            }
        }

        let root = Arc::new(Relay::start()?);
        let mut publisher = Publisher::over(
            RelayTransport::publisher(root.clone()),
            layout.clone(),
            views[0].clone(),
            1_000,
        )?
        .with_shards(4);
        let cfg = ControlConfig {
            fanout_cap: s,
            min_relay_levels: 1,
            heartbeat_interval: hb,
            missed_heartbeats: missed,
            ..Default::default()
        };
        let plane = ControlPlane::start(root.port, cfg)?;
        let nodes: Vec<ControlledNode> = (0..3)
            .map(|_| {
                ControlledNode::join_with_opts(plane.port, DEFAULT_QUEUE_DEPTH, INDEX_STEPS, hb)
            })
            .collect::<Result<_>>()?;
        let mut consumers: Vec<Consumer<ControlSubscriberTransport>> = Vec::new();
        for _ in 0..leaves_n {
            consumers.push(Consumer::over(
                ControlSubscriberTransport::join_with_heartbeat(plane.port, hb)?,
                layout.clone(),
            ));
        }
        let deadline = Deadline::after(Duration::from_secs(20));
        while plane.live_peers() != (3, leaves_n) {
            anyhow::ensure!(!deadline.expired(), "membership never settled");
            deadline.tick(Duration::from_millis(5));
        }

        for step in 1..=pre_steps {
            publisher.publish(step, &views[step as usize])?;
        }
        let synced = pool::par_map(consumers, |_, mut c| {
            let r = wait_sync(&mut c, pre_steps);
            (c, r)
        });
        consumers = Vec::with_capacity(synced.len());
        for (c, r) in synced {
            r?;
            consumers.push(c);
        }

        // assembly replans may already have moved leaves between
        // relays as the tree grew; the failover column must report the
        // kill's cost alone, so snapshot before crashing
        let reparents_before: u64 =
            consumers.iter().map(|c| c.transport.reparents()).sum();

        // victim = the relay parenting leaf 0; crash it silently
        let plan = plane.plan().unwrap();
        let parent_of = |id: u64| match plan.assignment_of(id).map(|a| a.upstream) {
            Some(Upstream::Peer(p)) => p,
            _ => 0,
        };
        let leaf_ids: Vec<u64> = consumers
            .iter()
            .map(|c| c.transport.peer_id().unwrap_or(0))
            .collect();
        let victim_id = parent_of(leaf_ids[0]);
        let orphan_set: Vec<bool> =
            leaf_ids.iter().map(|&id| parent_of(id) == victim_id).collect();
        let victim = nodes
            .iter()
            .find(|nd| nd.peer_id() == Some(victim_id))
            .ok_or_else(|| anyhow::anyhow!("victim relay not found"))?;
        let epoch_before = plane.epoch();
        let t_kill = Stopwatch::start();
        victim.fail_silently();
        let deadline = Deadline::after(Duration::from_secs(20));
        while plane.epoch() == epoch_before {
            anyhow::ensure!(!deadline.expired(), "death never detected");
            deadline.tick(Duration::from_millis(2));
        }
        let detect = t_kill.secs();

        // the recovery step: published after the kill, so a leaf
        // verifying it proves the subtree re-parented and caught up
        let rec_step = pre_steps + 1;
        publisher.publish(rec_step, &views[rec_step as usize])?;
        let synced = pool::par_map(consumers, |_, mut c| {
            let r = wait_sync(&mut c, rec_step);
            (c, r)
        });
        let recover = t_kill.secs();
        let (mut reparents_total, mut slow, mut patches, mut anchors) = (0u64, 0u64, 0u64, 0u64);
        consumers = Vec::with_capacity(synced.len());
        for (i, (c, r)) in synced.into_iter().enumerate() {
            let cs = r?;
            anyhow::ensure!(
                cs.verified && c.weights.as_deref() == Some(views[rec_step as usize].as_slice()),
                "leaf {} not bit-identical after failover",
                i
            );
            if orphan_set[i] {
                slow += (cs.path == SyncPath::Slow) as u64;
                patches += cs.patches_applied as u64;
                anchors += cs.anchors_restored as u64;
            }
            reparents_total += c.transport.reparents();
            consumers.push(c);
        }
        // the kill's cost alone (see snapshot above)
        let reparents = reparents_total.saturating_sub(reparents_before);
        let epoch = plane.epoch();

        csv.row(&[
            s.to_string(),
            leaves_n.to_string(),
            format!("{:.1}", detect * 1e3),
            format!("{:.1}", recover * 1e3),
            epoch.to_string(),
            reparents.to_string(),
            slow.to_string(),
            patches.to_string(),
            anchors.to_string(),
        ])?;
        rows.push(vec![
            format!("{}", s),
            format!("{}", leaves_n),
            format!("{:.0} ms", detect * 1e3),
            format!("{:.0} ms", recover * 1e3),
            epoch.to_string(),
            reparents.to_string(),
            slow.to_string(),
            patches.to_string(),
            anchors.to_string(),
        ]);

        drop(consumers);
        for nd in &nodes {
            nd.stop();
        }
        plane.stop();
        root.stop();
    }

    print_table(
        &format!(
            "Control plane: failover cost vs subtree size ({} params, {} pre-kill steps, \
             heartbeat {:?} × {} missed)",
            n, pre_steps, hb, missed
        ),
        &[
            "subtree",
            "leaves",
            "detect",
            "recover",
            "epoch",
            "reparents",
            "orphan slow",
            "catchup patches",
            "catchup anchors",
        ],
        &rows,
    );
    Ok(())
}

fn measure_sha_mbps() -> f64 {
    use sha2::{Digest, Sha256};
    let data = vec![7u8; 64 << 20];
    let t = Stopwatch::start();
    let mut h = Sha256::new();
    h.update(&data);
    std::hint::black_box(h.finalize());
    (data.len() as f64 / 1e6) / t.secs()
}

/// Verify throughput of the chunked hash tree: a parallel build over a
/// 64 MB buffer. This bounds the steady-state incremental update from
/// below — at uniform 1% density every chunk is touched, so the
/// incremental rehash degenerates to a (parallel) rebuild; clustered
/// updates only skip more.
fn measure_tree_mbps() -> f64 {
    use pulse::sparse::hashtree::{HashTree, DEFAULT_CHUNK_ELEMS};
    let data = vec![7u16; 32 << 20];
    let t = Stopwatch::start();
    std::hint::black_box(HashTree::build(&data, DEFAULT_CHUNK_ELEMS));
    ((data.len() * 2) as f64 / 1e6) / t.secs()
}

/// The CI scale gate: run the deterministic scale simulator (the real
/// planner / control-plane / relay / retry machinery in virtual time,
/// `src/sim`) at paper-scale leaf counts on a laptop-class runner.
///
/// Per leaf count it runs two profiles:
///   * `clean`  — lossless, churn-free; gated on a tight bytes-per-leaf
///     overhead ceiling (`--max-overhead`, default 5%): the fan-out
///     tree must deliver essentially exactly one copy per leaf.
///   * `churn`  — 0.2% frame loss plus a seeded churn script (crashes,
///     joins, slowdowns); gated on convergence and a loose waste bound
///     (`--max-churn-overhead`, default 200%): repairs, catch-up
///     replays, and store fallbacks may cost, but never runaway.
///
/// Every profile runs `--repeat` times (default 2) and the gate fails
/// unless all repeats are bit-identical — the replay/determinism
/// contract is enforced at full scale, not just in the unit tests.
/// Writes `results/sim_scale.csv` (one row per profile x size).
fn scale(args: &Args) -> Result<()> {
    use pulse::sim::churn::ChurnScript;
    use pulse::sim::topo::TopoSpec;
    use pulse::sim::{run, SimConfig, SimReport};
    use std::time::Duration;

    let leaves = args.usize_list_or("leaves", &[1_000, 10_000, 100_000]);
    let fanout = args.usize_or("fanout", 8);
    let seed = args.u64_or("seed", 42);
    let steps = args.u64_or("steps", 8);
    let repeat = args.usize_or("repeat", 2).max(1);
    let churn_events = args.usize_or("churn", 8);
    let max_overhead = args.f64_or("max-overhead", 5.0);
    let max_churn_overhead = args.f64_or("max-churn-overhead", 200.0);

    let mut lines = vec![format!("profile,{}", SimReport::csv_header())];
    let mut rows = Vec::new();
    for &n in &leaves {
        for profile in ["clean", "churn"] {
            // The run is a pure function of this config; rebuilding it
            // per repeat keeps the identity check honest.
            let mk = || {
                let mut cfg =
                    SimConfig::new(TopoSpec::kary(n, fanout).with_spares(2), seed);
                cfg.steps = steps;
                cfg.step_interval = Duration::from_millis(50);
                cfg.shards_per_step = 4;
                cfg.bytes_per_shard = 4096;
                cfg.anchor_bytes = 65536;
                if profile == "churn" {
                    cfg.link = cfg.link.with_loss(2_000); // 0.2% frame loss
                    cfg.churn = ChurnScript::seeded(
                        seed,
                        churn_events,
                        cfg.step_interval,
                        cfg.step_interval * steps as u32,
                    );
                }
                cfg
            };
            let wall = Stopwatch::start();
            let r = run(mk());
            let wall = wall.secs();
            for rerun in 1..repeat {
                let again = run(mk());
                anyhow::ensure!(
                    again == r,
                    "{} leaves ({}): repeat {} diverged from repeat 0 \
                     ({:016x} vs {:016x}) — determinism contract broken",
                    n,
                    profile,
                    rerun,
                    again.trace_hash,
                    r.trace_hash
                );
            }
            anyhow::ensure!(
                r.converged,
                "{} leaves ({}): failed to converge within the horizon: {:?}",
                n,
                profile,
                r
            );
            let ceiling =
                if profile == "clean" { max_overhead } else { max_churn_overhead };
            anyhow::ensure!(
                r.overhead_pct <= ceiling,
                "{} leaves ({}): bytes-per-leaf overhead {:.2}% exceeds the \
                 {:.0}% ceiling ({} vs ideal {})",
                n,
                profile,
                r.overhead_pct,
                ceiling,
                fmt_bytes(r.bytes_per_leaf),
                fmt_bytes(r.ideal_bytes_per_leaf)
            );
            lines.push(format!("{},{}", profile, r.csv_row()));
            rows.push(vec![
                n.to_string(),
                profile.to_string(),
                r.relays_live.to_string(),
                r.depth.to_string(),
                format!("{:.0}", r.settle.as_secs_f64() * 1e3),
                fmt_bytes(r.bytes_per_leaf),
                format!("{:+.2}%", r.overhead_pct),
                (r.leaf_nacks + r.slow_paths).to_string(),
                r.replans.to_string(),
                r.deaths.to_string(),
                r.events.to_string(),
                format!("{:.1}", wall),
            ]);
        }
    }

    let out = results_dir().join("sim_scale.csv");
    if let Some(p) = out.parent() {
        std::fs::create_dir_all(p)?;
    }
    std::fs::write(&out, lines.join("\n") + "\n")?;
    print_table(
        &format!(
            "sim scale gate (fanout {}, {} steps, seed {}, x{} repeats bit-identical)",
            fanout, steps, seed, repeat
        ),
        &[
            "leaves", "profile", "relays", "depth", "settle ms", "bytes/leaf",
            "overhead", "repairs", "replans", "deaths", "events", "wall s",
        ],
        &rows,
    );
    println!("wrote {}", out.display());
    Ok(())
}

// ====================================================== obs
/// Live node introspection: fetch one `OBS_SNAP` snapshot from any
/// sync-plane listener (relay root, mid-tier relay node, store server,
/// control plane — they all answer the same frame) and pretty-print
/// the JSON. `--events` additionally pulls the target's
/// flight-recorder ring.
fn obs_cmd(args: &Args) -> Result<()> {
    let addr = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("usage: paper obs <host:port|port> [--events]"))?;
    let flags = if args.flag("events") { pulse::obs::SNAP_WITH_EVENTS } else { 0 };
    let snap = pulse::obs::fetch_snapshot(addr, flags)?;
    println!("{}", snap.to_pretty());
    Ok(())
}

// ====================================================== trace
/// `results/trace.csv`: one row per pipeline stage with its offset
/// from the step's publish span.
fn write_trace_csv(mode: &str, report: &pulse::obs::TraceReport) -> Result<()> {
    let out = results_dir().join("trace.csv");
    let mut w =
        CsvWriter::create(&out, &["mode", "stage", "count", "p50_us", "p99_us", "max_us"])?;
    let mut rows = Vec::new();
    for r in &report.rows {
        let row = vec![
            mode.to_string(),
            r.stage.name().to_string(),
            r.count.to_string(),
            r.p50_us.to_string(),
            r.p99_us.to_string(),
            r.max_us.to_string(),
        ];
        w.row(&row)?;
        rows.push(row);
    }
    print_table(
        &format!(
            "per-stage timeline offsets ({}; {} timelines, {} complete)",
            mode, report.timelines, report.complete
        ),
        &["mode", "stage", "count", "p50 us", "p99 us", "max us"],
        &rows,
    );
    println!("wrote {}", out.display());
    Ok(())
}

/// Flight-recorder timeline reconstruction. Default mode drives a real
/// 2-level relay tree (root → 2 mid-tier nodes → leaves) through a
/// sharded stream, then reconstructs every `(step, shard)` timeline
/// from the process-global recorder: publish → relay stage → apply,
/// with per-stage p50/p99 offsets landing in `results/trace.csv`.
/// `--sim` instead replays the deterministic simulator twice, asserts
/// the span stream is bit-identical, and reconstructs from it.
fn trace(args: &Args) -> Result<()> {
    if args.flag("sim") {
        return trace_sim(args);
    }
    use pulse::net::node::RelayNode;
    use pulse::net::relay::Relay;
    use pulse::net::transport::RelayTransport;
    use pulse::pulse::sync::{Consumer, Publisher, SyncStats};
    use pulse::util::pool;
    use pulse::util::retry::Deadline;
    use pulse::util::rng::Rng;
    use std::sync::Arc;
    use std::time::Duration;

    fn wait_sync(c: &mut Consumer<RelayTransport>, step: u64) -> Result<SyncStats> {
        let deadline = Deadline::after(Duration::from_secs(30));
        loop {
            if let Some(head) = c.latest_ready()? {
                if head >= step {
                    return c.synchronize();
                }
            }
            anyhow::ensure!(!deadline.expired(), "step {} never became ready", step);
            deadline.tick(Duration::from_millis(2));
        }
    }

    let n = args.usize_or("params", 60_000);
    let steps = args.usize_or("steps", 6) as u64;
    let shards = args.usize_or("shards", 4).max(2);
    let subs = args.usize_or("subs", 4).max(2);

    let hub = pulse::obs::Obs::global();
    hub.clear();

    let layout = sparse::synthetic_layout(n, 1024);
    let mut rng = Rng::new(11);
    let init: Vec<u16> = (0..n).map(|_| rng.next_u32() as u16).collect();

    let root = Arc::new(Relay::start()?);
    let node_a = RelayNode::join(root.port)?;
    let node_b = RelayNode::join(root.port)?;
    let deadline = Deadline::after(Duration::from_secs(5));
    while (node_a.hop() != 1 || node_b.hop() != 1) && !deadline.expired() {
        deadline.tick(Duration::from_millis(3));
    }

    let mut publisher =
        Publisher::over(RelayTransport::publisher(root.clone()), layout.clone(), init.clone(), 6)?
            .with_shards(shards);
    let mut consumers: Vec<Consumer<RelayTransport>> = Vec::new();
    for i in 0..subs {
        let p = if i % 2 == 0 { node_a.port() } else { node_b.port() };
        consumers.push(Consumer::over(RelayTransport::subscribe(p)?, layout.clone()));
    }
    let started = pool::par_map(consumers, |_, mut c| {
        let r = wait_sync(&mut c, 0);
        (c, r)
    });
    consumers = Vec::with_capacity(started.len());
    for (c, r) in started {
        r?;
        consumers.push(c);
    }

    let mut w = init;
    for step in 1..=steps {
        for _ in 0..n / 100 {
            let i = rng.below(n as u64) as usize;
            w[i] = rng.next_u32() as u16;
        }
        publisher.publish(step, &w)?;
        let synced = pool::par_map(consumers, |_, mut c| {
            let r = wait_sync(&mut c, step);
            (c, r)
        });
        consumers = Vec::with_capacity(synced.len());
        for (c, r) in synced {
            let cs = r?;
            anyhow::ensure!(
                cs.verified && c.weights.as_deref() == Some(w.as_slice()),
                "bit-identity broken at step {}",
                step
            );
            consumers.push(c);
        }
    }

    // snapshot before teardown so shutdown noise cannot land in the
    // trace; step 0 is the bootstrap anchor, which by design has no
    // publish span (leaves restore it via the catch-up path)
    let events: Vec<pulse::obs::SpanEvent> = hub
        .recorder
        .snapshot()
        .into_iter()
        .filter(|e| e.step >= 1 && e.step <= steps)
        .collect();
    node_a.stop();
    node_b.stop();
    root.stop();

    let report = pulse::obs::reconstruct(&events);
    anyhow::ensure!(
        report.is_complete(),
        "trace reconstruction incomplete: {} of {} timelines missing an endpoint ({:?})",
        report.incomplete.len(),
        report.timelines,
        report.incomplete
    );
    write_trace_csv("tree", &report)?;
    // the run also fed the latency histograms (e2e step, catch-up,
    // NACK repair) — land their quantiles next to the trace
    let hist_out = results_dir().join("obs_hist.csv");
    pulse::coordinator::metrics::ObsExport::new().write_csv(&hist_out)?;
    println!("wrote {}", hist_out.display());
    println!(
        "real-tree trace: {} leaves x {} steps x {} shards over 2 hops — {} timelines, all complete",
        subs, steps, shards, report.timelines
    );
    Ok(())
}

/// The `--sim` leg of `paper trace`: run the deterministic simulator
/// twice with a recorder sized to keep *every* span, assert the span
/// stream replays bit-identically (hash and events), and reconstruct
/// the timelines the same way the real-tree mode does.
fn trace_sim(args: &Args) -> Result<()> {
    use pulse::sim::topo::TopoSpec;
    use pulse::sim::{run, SimConfig};
    use std::time::Duration;

    let n = args.usize_or("leaves", 10_000);
    let fanout = args.usize_or("fanout", 8);
    let seed = args.u64_or("seed", 42);
    let steps = args.u64_or("steps", 8);

    let mk = || {
        let mut cfg = SimConfig::new(TopoSpec::kary(n, fanout).with_spares(2), seed);
        cfg.steps = steps;
        cfg.step_interval = Duration::from_millis(50);
        cfg.shards_per_step = 4;
        cfg.bytes_per_shard = 4096;
        cfg.anchor_bytes = 65536;
        // keep the whole span stream: reconstruction needs every
        // event, not the newest-ring the scale gate keeps
        cfg.recorder_capacity = n * steps as usize * 8 + 65_536;
        cfg
    };
    let t = Stopwatch::start();
    let r = run(mk());
    let again = run(mk());
    anyhow::ensure!(
        r.span_hash == again.span_hash && r == again,
        "span stream diverged across replays: {:016x} vs {:016x} — determinism contract broken",
        r.span_hash,
        again.span_hash
    );
    anyhow::ensure!(
        r.converged,
        "trace sim failed to converge (head {} at {:?})",
        r.head_step,
        r.converged_at
    );
    anyhow::ensure!(
        r.spans as usize == r.span_events.len(),
        "recorder ring dropped spans ({} retained of {}) — capacity estimate too small",
        r.span_events.len(),
        r.spans
    );
    let report = pulse::obs::reconstruct(&r.span_events);
    anyhow::ensure!(
        report.is_complete(),
        "sim trace reconstruction incomplete: {} of {} timelines missing an endpoint",
        report.incomplete.len(),
        report.timelines
    );
    write_trace_csv("sim", &report)?;
    println!(
        "sim trace: {} leaves, {} spans, span_hash {:016x} (bit-identical x2), \
         {} timelines complete in {:.1}s",
        n,
        r.spans,
        r.span_hash,
        report.complete,
        t.secs()
    );
    Ok(())
}

// ====================================================== lint
/// The CI static-analysis gate: scan `rust/src` with the in-tree lint
/// (`analysis::lint`) — clock-seam, retry-discipline, panic-free wire
/// paths, bounded channels, frame-kind coverage, counter↔CSV drift.
/// Prints the human report, writes the machine report to `--json`
/// (default `results/lint.json`), and fails on any active finding;
/// pragma-suppressed findings are listed as the audit trail but pass.
fn lint(args: &Args) -> Result<()> {
    let src_root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = analysis::lint::run_lint(&src_root)?;
    print!("{}", report.render());
    let json_path = match args.get("json") {
        Some(p) => std::path::PathBuf::from(p),
        None => results_dir().join("lint.json"),
    };
    if let Some(p) = json_path.parent() {
        std::fs::create_dir_all(p)?;
    }
    std::fs::write(&json_path, report.to_json().to_pretty())?;
    eprintln!("[paper lint] report: {}", json_path.display());
    anyhow::ensure!(
        report.is_clean(),
        "{} active lint finding(s) — fix them or justify with \
         `// pallas-lint: allow(rule): <why>`",
        report.active().count()
    );
    Ok(())
}

/// The CI bench regression guard: diff every `results/BENCH_*.json`
/// snapshot produced by this run's benches against the checked-in
/// baseline (`ci/bench_baseline.json`) and fail on any row whose
/// mean regressed beyond `--max-regress` (default 0.25 = +25%).
///
/// Only rows named in the baseline are gated — new benches ride along
/// ungated until the baseline is refreshed with
/// `paper benchguard --update` (run it on a green CI runner and check
/// in the result). Baseline rows missing from the current run are
/// reported but don't fail, so self-skipping benches (e.g. the
/// artifact-gated train-step row) stay compatible; a run where *no*
/// baseline row matched fails loudly instead of passing vacuously.
fn benchguard(args: &Args) -> Result<()> {
    use pulse::util::json::Json;
    use std::path::{Path, PathBuf};

    let max_regress = args.f64_or("max-regress", 0.25);
    let raw = PathBuf::from(args.str_or("baseline", "ci/bench_baseline.json"));
    // Resolve relative paths that don't exist under the cwd against
    // the repo root (parent of the crate manifest), so the command
    // works from the workspace root or from `rust/`.
    let baseline_path = if raw.is_relative() && !raw.exists() {
        Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .map(|p| p.join(&raw))
            .unwrap_or(raw)
    } else {
        raw
    };

    let dir = results_dir();
    let mut files: Vec<PathBuf> = std::fs::read_dir(&dir)
        .map_err(|e| {
            anyhow::anyhow!(
                "no results dir at {} — run the benches first: {}",
                dir.display(),
                e
            )
        })?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|f| f.to_str())
                .map(|f| f.starts_with("BENCH_") && f.ends_with(".json"))
                .unwrap_or(false)
        })
        .collect();
    files.sort();
    anyhow::ensure!(
        !files.is_empty(),
        "no BENCH_*.json under {} — run `cargo bench` first",
        dir.display()
    );

    let mut current: Vec<(String, f64)> = Vec::new();
    for f in &files {
        let j = Json::parse_file(f)?;
        for row in j.req("results")?.as_arr().unwrap_or(&[]) {
            current.push((row.req_str("name")?.to_string(), row.req_f64("mean_ns")?));
        }
    }

    if args.flag("update") {
        current.sort_by(|a, b| a.0.cmp(&b.0));
        let rows: Vec<Json> = current
            .iter()
            .map(|(name, mean_ns)| {
                let mut j = Json::obj();
                j.set("name", name.as_str().into()).set("mean_ns", (*mean_ns).into());
                j
            })
            .collect();
        let mut root = Json::obj();
        root.set(
            "note",
            "mean_ns ceilings for `paper benchguard`; refresh on a green CI \
             runner with `paper benchguard --update`"
                .into(),
        )
        .set("results", Json::Arr(rows));
        if let Some(p) = baseline_path.parent() {
            std::fs::create_dir_all(p)?;
        }
        std::fs::write(&baseline_path, root.to_pretty())?;
        println!("wrote {} ({} rows)", baseline_path.display(), current.len());
        return Ok(());
    }

    let fmt_ns = |ns: f64| {
        if ns < 1e3 {
            format!("{:.0} ns", ns)
        } else if ns < 1e6 {
            format!("{:.1} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.2} s", ns / 1e9)
        }
    };
    let base = Json::parse_file(&baseline_path).map_err(|e| {
        anyhow::anyhow!("cannot read baseline {}: {}", baseline_path.display(), e)
    })?;
    let mut rows = Vec::new();
    let mut regressions: Vec<String> = Vec::new();
    let mut matched = 0usize;
    for brow in base.req("results")?.as_arr().unwrap_or(&[]) {
        let name = brow.req_str("name")?;
        let base_ns = brow.req_f64("mean_ns")?;
        let Some((_, cur)) = current.iter().find(|(n, _)| n.as_str() == name) else {
            rows.push(vec![
                name.to_string(),
                fmt_ns(base_ns),
                "-".to_string(),
                "-".to_string(),
                "not run".to_string(),
            ]);
            continue;
        };
        matched += 1;
        let cur_ns = *cur;
        let delta = cur_ns / base_ns - 1.0;
        let verdict = if delta > max_regress {
            regressions.push(format!("{} ({:+.0}%)", name, delta * 100.0));
            "REGRESSED"
        } else if delta < -max_regress {
            "faster — consider --update"
        } else {
            "ok"
        };
        rows.push(vec![
            name.to_string(),
            fmt_ns(base_ns),
            fmt_ns(cur_ns),
            format!("{:+.1}%", delta * 100.0),
            verdict.to_string(),
        ]);
    }
    print_table(
        &format!(
            "bench guard vs {} (fail beyond +{:.0}%)",
            baseline_path.display(),
            max_regress * 100.0
        ),
        &["bench", "baseline", "current", "delta", "verdict"],
        &rows,
    );
    anyhow::ensure!(
        matched > 0,
        "no baseline row matched any current bench — wrong results dir or \
         stale baseline names"
    );
    anyhow::ensure!(
        regressions.is_empty(),
        "bench regression(s) beyond +{:.0}%: {}",
        max_regress * 100.0,
        regressions.join(", ")
    );
    Ok(())
}
