//! grail — the decentralized RL deployment substrate (paper §E).
//!
//! Three node roles coordinate exclusively through the S3-like object
//! store: **miners** pull the latest checkpoint via a PULSESync
//! [`Consumer`], generate rollouts and upload them with grail-Proof
//! sketches; **validators** recompute logprobs under the claimed
//! checkpoint and mark uploads verified; the **trainer** consumes
//! verified rollouts through a staleness-weighted [`replay`] buffer,
//! performs GRPO/AdamW updates, and publishes sparse BF16 patches via a
//! PULSESync [`Publisher`] at window boundaries.
//!
//! [`GrailSim`] drives all roles in-process against one shared compiled
//! runtime (each role keeps its *own weights*; see DESIGN.md §2 for the
//! substitution ledger versus the paper's live deployment).

pub mod proof;
pub mod replay;

use crate::optim::{AdamConfig, AdamW};
use crate::pulse::sync::{Consumer, Publisher};
use crate::rl::{grpo, Instance, Task};
use crate::runtime::ModelRuntime;
use crate::storage::ObjectStore;
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use replay::{Entry, ReplayBuffer, ReplayConfig};

/// Serialize a rollout upload (tokens + logprobs + proofs + instances).
pub fn encode_rollout(entry: &Entry, proofs: &[Vec<u32>], beacon: u64) -> String {
    let mut j = Json::obj();
    j.set("window", entry.window.into())
        .set("miner", entry.miner.into())
        .set("beacon", beacon.into())
        .set("tokens", Json::Arr(entry.tokens.iter().map(|&t| (t as i64).into()).collect()))
        .set(
            "logprobs",
            Json::Arr(entry.logprobs.iter().map(|&x| (x as f64).into()).collect()),
        )
        .set(
            "proofs",
            Json::Arr(
                proofs
                    .iter()
                    .map(|row| Json::Arr(row.iter().map(|&p| (p as u64).into()).collect()))
                    .collect(),
            ),
        )
        .set("instances", Json::Arr(entry.instances.iter().map(encode_instance).collect()));
    j.to_string()
}

fn encode_instance(inst: &Instance) -> Json {
    let mut j = Json::obj();
    match inst {
        Instance::Math { answer } => {
            j.set("kind", "math".into()).set(
                "answer",
                Json::Arr(answer.iter().map(|&d| (d as u64).into()).collect()),
            );
        }
        Instance::Code { tests } => {
            j.set("kind", "code".into()).set(
                "tests",
                Json::Arr(
                    tests
                        .iter()
                        .map(|(x, y)| Json::Arr(vec![(*x).into(), (*y).into()]))
                        .collect(),
                ),
            );
        }
    }
    j
}

fn decode_instance(j: &Json) -> Result<Instance> {
    match j.req_str("kind")? {
        "math" => Ok(Instance::Math {
            answer: j
                .req("answer")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_f64())
                .map(|x| x as u8)
                .collect(),
        }),
        "code" => Ok(Instance::Code {
            tests: j
                .req("tests")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|t| {
                    Some((t.idx(0)?.as_i64()?, t.idx(1)?.as_i64()?))
                })
                .collect(),
        }),
        other => bail!("unknown instance kind '{}'", other),
    }
}

/// Parse a rollout upload back into (entry, proofs, beacon).
pub fn decode_rollout(text: &str) -> Result<(Entry, Vec<Vec<u32>>, u64)> {
    let j = Json::parse(text)?;
    let entry = Entry {
        window: j.req_f64("window")? as u64,
        miner: j.req_f64("miner")? as usize,
        tokens: j
            .req("tokens")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|x| x.as_i64())
            .map(|x| x as i32)
            .collect(),
        logprobs: j
            .req("logprobs")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|x| x.as_f64())
            .map(|x| x as f32)
            .collect(),
        instances: j
            .req("instances")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(decode_instance)
            .collect::<Result<Vec<_>>>()?,
    };
    let proofs: Vec<Vec<u32>> = j
        .req("proofs")?
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .map(|row| {
            row.as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_f64())
                .map(|x| x as u32)
                .collect()
        })
        .collect();
    Ok((entry, proofs, j.req_f64("beacon")? as u64))
}

/// Per-window statistics (the Fig. 6 series).
#[derive(Debug, Clone, Default)]
pub struct WindowStats {
    pub window: u64,
    pub pass_at_1: f64,
    pub upload_bytes: u64,
    pub full_checkpoint_bytes: u64,
    pub verified: usize,
    pub rejected: usize,
    pub train_steps: usize,
    pub mean_reward: f64,
    pub replay_mean_age: f64,
}

/// Configuration for the in-process deployment simulation.
#[derive(Debug, Clone, Copy)]
pub struct GrailConfig {
    pub n_miners: usize,
    /// Optimizer steps per window (paper: "up to 8 per ~6 min window").
    pub steps_per_window: usize,
    /// Rollout batches each miner uploads per window.
    pub batches_per_miner: usize,
    /// PULSESync anchor interval k.
    pub anchor_interval: u64,
    /// grail-Proof bucket tolerance.
    pub proof_tolerance: i32,
    /// Evaluation problems for pass@1.
    pub n_eval: usize,
}

impl Default for GrailConfig {
    fn default() -> Self {
        GrailConfig {
            n_miners: 2,
            steps_per_window: 4,
            batches_per_miner: 1,
            anchor_interval: 50,
            proof_tolerance: 2,
            n_eval: 64,
        }
    }
}

/// The in-process grail deployment: one trainer, N miners, one
/// validator, coordinating via an object store.
pub struct GrailSim<'a> {
    pub rt: &'a ModelRuntime,
    pub task: &'a dyn Task,
    pub cfg: GrailConfig,
    pub grpo: grpo::GrpoConfig,
    pub store: ObjectStore,
    pub publisher: Publisher,
    /// One consumer per miner + one for the validator.
    pub miners: Vec<Consumer>,
    pub validator: Consumer,
    pub replay: ReplayBuffer,
    pub master: Vec<f32>,
    pub opt: AdamW,
    pub step: u64,
    pub rng: Rng,
}

impl<'a> GrailSim<'a> {
    pub fn new(
        rt: &'a ModelRuntime,
        task: &'a dyn Task,
        cfg: GrailConfig,
        master: Vec<f32>,
        adam: AdamConfig,
        seed: u64,
    ) -> Result<GrailSim<'a>> {
        let store = ObjectStore::temp("grail")?;
        let layout = rt.manifest.layout.clone();
        let mut bf16_view = Vec::new();
        crate::bf16::cast_slice_par(&master, &mut bf16_view);
        let publisher = Publisher::new(
            store.clone(),
            "ckpt",
            layout.clone(),
            bf16_view,
            cfg.anchor_interval,
        )?;
        let miners =
            (0..cfg.n_miners).map(|_| Consumer::new(store.clone(), "ckpt", layout.clone())).collect();
        let validator = Consumer::new(store.clone(), "ckpt", layout.clone());
        let n = master.len();
        Ok(GrailSim {
            rt,
            task,
            cfg,
            grpo: grpo::GrpoConfig::default(),
            store,
            publisher,
            miners,
            validator,
            replay: ReplayBuffer::new(ReplayConfig::default()),
            master,
            opt: AdamW::new(n, adam),
            step: 0,
            rng: Rng::new(seed),
        })
    }

    /// Expand a consumer's BF16 weights to the f32 vector the runtime
    /// takes (bit-exact: bf16 → f32 widening is lossless).
    fn consumer_f32(c: &Consumer) -> Vec<f32> {
        c.weights
            .as_ref()
            .expect("consumer not synchronized")
            .iter()
            .map(|&b| crate::bf16::bf16_bits_to_f32(b))
            .collect()
    }

    /// Run one full window: miners sync + generate + upload; validator
    /// verifies; trainer trains and publishes. Returns the window stats.
    pub fn run_window(&mut self, window: u64) -> Result<WindowStats> {
        let beacon = 0x6A11u64 ^ window;
        let mut stats = WindowStats { window, ..Default::default() };
        let d = self.rt.manifest.dims.clone();

        // -- miners: sync to latest checkpoint, roll out, upload
        for m in 0..self.cfg.n_miners {
            self.miners[m].synchronize()?;
            let flat = Self::consumer_f32(&self.miners[m]);
            for b in 0..self.cfg.batches_per_miner {
                let (prompts, instances) = grpo::sample_prompts(
                    self.task,
                    d.batch,
                    d.prompt_len,
                    self.grpo.group,
                    &mut self.rng,
                );
                let key = [self.rng.next_u32(), self.rng.next_u32()];
                let ro = self.rt.rollout(&flat, &prompts, key, self.grpo.temperature)?;
                let entry = Entry {
                    window,
                    miner: m,
                    tokens: ro.tokens.clone(),
                    logprobs: ro.logprobs.clone(),
                    instances,
                };
                // per-row proofs over the generated tokens
                let proofs: Vec<Vec<u32>> = (0..d.batch)
                    .map(|row| {
                        let toks =
                            &ro.tokens[row * d.seq + d.prompt_len..(row + 1) * d.seq];
                        let lps = &ro.logprobs[row * d.gen_len..(row + 1) * d.gen_len];
                        proof::prove(beacon, toks, lps)
                    })
                    .collect();
                let body = encode_rollout(&entry, &proofs, beacon);
                self.store.put(
                    &format!("rollouts/w{:06}/miner{}_b{}.json", window, m, b),
                    body.as_bytes(),
                )?;
            }
        }

        // -- validator: recompute logprobs under the claimed checkpoint
        self.validator.synchronize()?;
        let vflat = Self::consumer_f32(&self.validator);
        for key in self.store.list(&format!("rollouts/w{:06}", window))? {
            let (entry, proofs, beacon_claimed) =
                decode_rollout(&String::from_utf8(self.store.get(&key)?)?)
                    .with_context(|| key.clone())?;
            let (relp, _) = self.rt.score(&vflat, &entry.tokens)?;
            let mut ok = beacon_claimed == beacon;
            if ok {
                for row in 0..d.batch {
                    let toks =
                        &entry.tokens[row * d.seq + d.prompt_len..(row + 1) * d.seq];
                    let lps = &relp[row * d.gen_len..(row + 1) * d.gen_len];
                    if !proof::verify(beacon, toks, lps, &proofs[row], self.cfg.proof_tolerance)
                    {
                        ok = false;
                        break;
                    }
                }
            }
            if ok {
                stats.verified += 1;
                self.store.put(&format!("{}.verified", key), b"")?;
                self.replay.push(entry);
            } else {
                stats.rejected += 1;
            }
        }
        self.replay.advance_window(window);

        // -- trainer: sample replay, GRPO + AdamW, publish patches
        for _ in 0..self.cfg.steps_per_window {
            if self.replay.is_empty() {
                break;
            }
            let entry = self.replay.sample(1, &mut self.rng)[0].clone();
            let batch = grpo::build_batch(
                &d,
                self.task,
                &entry.instances,
                entry.tokens,
                entry.logprobs,
                self.grpo,
            )?;
            let out = self.rt.grad(
                &self.master,
                &batch.tokens,
                &batch.advantages,
                &batch.old_logprobs,
                &batch.mask,
            )?;
            self.opt.step(&mut self.master, &out.grads);
            self.step += 1;
            stats.train_steps += 1;
            stats.mean_reward = batch.mean_reward;
            // publish the new BF16 view as a sparse patch
            let mut view = Vec::new();
            crate::bf16::cast_slice_par(&self.master, &mut view);
            let ps = self.publisher.publish(self.step, &view)?;
            stats.upload_bytes += ps.patch_bytes;
        }
        stats.full_checkpoint_bytes =
            (self.rt.manifest.n_params * 2 * stats.train_steps.max(1)) as u64;
        stats.replay_mean_age = self.replay.mean_age();

        // -- evaluation: greedy pass@1 on fresh problems with the
        //    *published* checkpoint (what inference workers serve)
        let mut eval_consumer =
            Consumer::new(self.store.clone(), "ckpt", self.rt.manifest.layout.clone());
        eval_consumer.synchronize()?;
        let eflat = Self::consumer_f32(&eval_consumer);
        stats.pass_at_1 =
            grpo::pass_at_1(self.rt, &eflat, self.task, self.cfg.n_eval, &mut self.rng)?;
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rollout_roundtrip_json() {
        let entry = Entry {
            window: 3,
            miner: 1,
            tokens: vec![1, 2, 3, 4],
            logprobs: vec![-0.5, -1.25],
            instances: vec![
                Instance::Math { answer: vec![4, 2] },
                Instance::Code { tests: vec![(2, 4), (-3, 9)] },
            ],
        };
        let proofs = vec![vec![1u32, 2, 3], vec![4, 5, 6]];
        let text = encode_rollout(&entry, &proofs, 99);
        let (e2, p2, b2) = decode_rollout(&text).unwrap();
        assert_eq!(e2.window, 3);
        assert_eq!(e2.miner, 1);
        assert_eq!(e2.tokens, entry.tokens);
        assert_eq!(e2.logprobs, entry.logprobs);
        assert_eq!(p2, proofs);
        assert_eq!(b2, 99);
        match &e2.instances[1] {
            Instance::Code { tests } => assert_eq!(tests, &vec![(2, 4), (-3, 9)]),
            _ => panic!("wrong instance"),
        }
    }
}
