//! grail Proof — rollout-authenticity verification (paper §E.3).
//!
//! Miners commit to the model outputs that produced each rollout:
//! per generated token, the behaviour-policy logprob is log-quantized
//! (heavy-tailed activations → log buckets) and hashed together with
//! the token id, position and a per-window beacon into a 4-byte sketch.
//! Validators recompute logprobs under the *claimed checkpoint* with
//! their own runtime and accept a sketch if it matches within an
//! adaptive tolerance of ±1 quantization bucket (numerical drift across
//! hardware). A miner serving a stale or modified checkpoint produces
//! logprobs in different buckets and fails verification.
//!
//! (The paper sketches top-32 hidden-state dimensions; we commit to
//! per-token logprobs — the same "cheap commitment to model internals"
//! mechanism using what our runtime exposes. DESIGN.md §2.)

use sha2::{Digest, Sha256};

/// Bucket width in log-probability space. Cross-hardware numerical
/// drift moves logprobs by ≲1e-3 nats (well inside ±1 bucket at
/// tolerance 1), while even one optimizer step at RL learning rates
/// moves sampled-token logprobs by ≫0.04 nats once training is under
/// way — so stale/modified checkpoints fail verification.
pub const BUCKET_NATS: f32 = 0.02;

/// Quantization: linear buckets in log-probability (= logarithmic in
/// probability, handling the heavy-tailed distribution), clamped.
pub fn log_quantize(x: f32) -> i32 {
    let b = (x / BUCKET_NATS).round();
    b.clamp(-1e6, 1e6) as i32
}

/// 4-byte sketch of (beacon, position, token, bucket).
pub fn sketch(beacon: u64, pos: usize, token: i32, bucket: i32) -> u32 {
    let mut h = Sha256::new();
    h.update(beacon.to_le_bytes());
    h.update((pos as u64).to_le_bytes());
    h.update(token.to_le_bytes());
    h.update(bucket.to_le_bytes());
    let d = h.finalize();
    u32::from_le_bytes([d[0], d[1], d[2], d[3]])
}

/// Miner side: sketch every generated token of a rollout row.
pub fn prove(beacon: u64, tokens: &[i32], logprobs: &[f32]) -> Vec<u32> {
    assert_eq!(tokens.len(), logprobs.len());
    tokens
        .iter()
        .zip(logprobs)
        .enumerate()
        .map(|(i, (&t, &lp))| sketch(beacon, i, t, log_quantize(lp)))
        .collect()
}

/// Validator side: accept if every sketch matches the recomputed
/// logprob's bucket within ±`tolerance` buckets.
pub fn verify(
    beacon: u64,
    tokens: &[i32],
    recomputed_logprobs: &[f32],
    proofs: &[u32],
    tolerance: i32,
) -> bool {
    if tokens.len() != recomputed_logprobs.len() || tokens.len() != proofs.len() {
        return false;
    }
    for (i, ((&t, &lp), &p)) in tokens.iter().zip(recomputed_logprobs).zip(proofs).enumerate()
    {
        let b = log_quantize(lp);
        let ok = (-tolerance..=tolerance).any(|db| sketch(beacon, i, t, b + db) == p);
        if !ok {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn honest_prover_verifies_under_drift() {
        let mut rng = Rng::new(1);
        let tokens: Vec<i32> = (0..64).map(|_| rng.below(64) as i32).collect();
        let lps: Vec<f32> = (0..64).map(|_| -(rng.f32() * 8.0 + 1e-3)).collect();
        let proofs = prove(42, &tokens, &lps);
        assert!(verify(42, &tokens, &lps, &proofs, 1));
        // cross-hardware numeric drift (≤5e-3 nats) stays within ±1
        // bucket at width 0.02
        let drifted: Vec<f32> = lps.iter().map(|&x| x + 0.005).collect();
        assert!(verify(42, &tokens, &drifted, &proofs, 1));
    }

    #[test]
    fn wrong_checkpoint_rejected() {
        let mut rng = Rng::new(2);
        let tokens: Vec<i32> = (0..64).map(|_| rng.below(64) as i32).collect();
        let lps: Vec<f32> = (0..64).map(|_| -(rng.f32() * 8.0 + 1e-3)).collect();
        let proofs = prove(42, &tokens, &lps);
        // a different model's logprobs differ well beyond a bucket
        let other: Vec<f32> = lps.iter().map(|&x| x * 2.5 - 0.7).collect();
        assert!(!verify(42, &tokens, &other, &proofs, 1));
    }

    #[test]
    fn tampered_tokens_or_beacon_rejected() {
        let tokens = vec![5, 6, 7, 8];
        let lps = vec![-0.5, -1.0, -2.0, -4.0];
        let proofs = prove(7, &tokens, &lps);
        let mut tampered = tokens.clone();
        tampered[2] = 9;
        assert!(!verify(7, &tampered, &lps, &proofs, 1));
        assert!(!verify(8, &tokens, &lps, &proofs, 1));
        assert!(!verify(7, &tokens, &lps[..3], &proofs, 1));
    }

    #[test]
    fn quantizer_is_monotone() {
        let mut last = i32::MIN;
        for i in -300..300 {
            let b = log_quantize(i as f32 * 0.03);
            assert!(b >= last);
            last = b;
        }
        // sign separation and resolution
        assert_ne!(log_quantize(0.5), log_quantize(-0.5));
        assert_ne!(log_quantize(-4.0), log_quantize(-4.05));
    }
}
