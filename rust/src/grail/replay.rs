//! Replay buffer (paper §E.2): decouples rollout arrival from training
//! consumption. Stores rollouts from multiple windows, supports
//! staleness-weighted sampling (fresher data preferred), and evicts
//! entries older than a window horizon.

use crate::util::rng::Rng;

/// One stored verified rollout *batch* ([B,T] tokens + [B,G] logprobs
/// + per-row problem instances) — the unit miners upload and the
/// trainer samples.
#[derive(Debug, Clone)]
pub struct Entry {
    pub window: u64,
    /// [B*T] row-major batch tokens.
    pub tokens: Vec<i32>,
    /// [B*G] behaviour logprobs.
    pub logprobs: Vec<f32>,
    pub instances: Vec<crate::rl::Instance>,
    /// Which miner produced it (for diagnostics).
    pub miner: usize,
}

#[derive(Debug, Clone, Copy)]
pub struct ReplayConfig {
    /// Entries older than `current_window - max_age` are evicted.
    pub max_age: u64,
    /// Exponential staleness discount per window of age.
    pub staleness_decay: f64,
    /// Hard capacity (entries), oldest evicted first.
    pub capacity: usize,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        ReplayConfig { max_age: 4, staleness_decay: 0.5, capacity: 4096 }
    }
}

pub struct ReplayBuffer {
    pub cfg: ReplayConfig,
    entries: Vec<Entry>,
    current_window: u64,
}

impl ReplayBuffer {
    pub fn new(cfg: ReplayConfig) -> Self {
        ReplayBuffer { cfg, entries: Vec::new(), current_window: 0 }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Advance to a new window: evict stale entries.
    pub fn advance_window(&mut self, window: u64) {
        self.current_window = window;
        let horizon = window.saturating_sub(self.cfg.max_age);
        self.entries.retain(|e| e.window >= horizon);
    }

    pub fn push(&mut self, entry: Entry) {
        if self.entries.len() >= self.cfg.capacity {
            // evict the oldest (min window, then FIFO)
            if let Some((idx, _)) = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(i, e)| (e.window, *i))
            {
                self.entries.remove(idx);
            }
        }
        self.entries.push(entry);
    }

    /// Staleness-weighted sample of `n` entries (with replacement):
    /// weight = decay^(current_window - entry.window).
    pub fn sample(&self, n: usize, rng: &mut Rng) -> Vec<&Entry> {
        assert!(!self.entries.is_empty(), "sampling from empty replay buffer");
        let weights: Vec<f64> = self
            .entries
            .iter()
            .map(|e| {
                self.cfg
                    .staleness_decay
                    .powi((self.current_window.saturating_sub(e.window)) as i32)
            })
            .collect();
        (0..n).map(|_| &self.entries[rng.weighted(&weights)]).collect()
    }

    /// Mean staleness of stored entries (diagnostic).
    pub fn mean_age(&self) -> f64 {
        if self.entries.is_empty() {
            return 0.0;
        }
        self.entries
            .iter()
            .map(|e| (self.current_window.saturating_sub(e.window)) as f64)
            .sum::<f64>()
            / self.entries.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rl::Instance;

    fn entry(window: u64) -> Entry {
        Entry {
            window,
            tokens: vec![1, 2, 3],
            logprobs: vec![-0.1],
            instances: vec![Instance::Math { answer: vec![1] }],
            miner: 0,
        }
    }

    #[test]
    fn eviction_by_age_and_capacity() {
        let mut rb = ReplayBuffer::new(ReplayConfig {
            max_age: 2,
            staleness_decay: 0.5,
            capacity: 3,
        });
        rb.push(entry(0));
        rb.push(entry(1));
        rb.push(entry(2));
        rb.push(entry(3)); // over capacity → evicts window 0
        assert_eq!(rb.len(), 3);
        assert!(rb.entries.iter().all(|e| e.window >= 1));
        rb.advance_window(5); // horizon = 3 → windows 1,2 evicted
        assert_eq!(rb.len(), 1);
        assert_eq!(rb.entries[0].window, 3);
    }

    #[test]
    fn sampling_prefers_fresh() {
        let mut rb = ReplayBuffer::new(ReplayConfig::default());
        for _ in 0..50 {
            rb.push(entry(0));
        }
        for _ in 0..50 {
            rb.push(entry(4));
        }
        rb.advance_window(4);
        let mut rng = Rng::new(1);
        let samples = rb.sample(2000, &mut rng);
        let fresh = samples.iter().filter(|e| e.window == 4).count();
        // decay 0.5^4 = 1/16 weight for stale → expect ≈ 16/17 fresh
        assert!(fresh > 1700, "fresh {}", fresh);
    }

    #[test]
    fn mean_age_tracks() {
        let mut rb = ReplayBuffer::new(ReplayConfig::default());
        rb.push(entry(0));
        rb.push(entry(2));
        rb.advance_window(2);
        assert!((rb.mean_age() - 1.0).abs() < 1e-12);
    }
}
