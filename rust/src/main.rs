//! `pulse` — the leader CLI.
//!
//! Subcommands:
//!   train   — run GRPO training with a chosen trainer-sync method
//!             (single / ddp / diloco / pulseloco), logging step/round
//!             metrics to CSV.
//!   grail   — run the grail deployment simulation (trainer + miners +
//!             validator over an object store with PULSESync patches).
//!   sync    — demonstrate PULSESync publisher/consumer over a local
//!             object store for a given model size.
//!   info    — print manifest/runtime information for a model size.
//!
//! Examples:
//!   pulse train --size tiny --method pulseloco --workers 4 --local-steps 8 --steps 64
//!   pulse grail --size tiny --windows 5
//!   pulse info --size med

use anyhow::Result;
use pulse::coordinator::{self, metrics, Method, TaskKind, TrainConfig};
use pulse::optim::AdamConfig;
use pulse::rl::grpo::GrpoConfig;
use pulse::runtime::{artifacts_dir, ModelRuntime};
use pulse::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "train" => cmd_train(&args),
        "grail" => cmd_grail(&args),
        "sync" => cmd_sync(&args),
        "info" => cmd_info(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {:#}", e);
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "pulse — compute-visible sparsification for distributed RL\n\
         \n\
         USAGE: pulse <command> [--options]\n\
         \n\
         COMMANDS:\n\
           train   GRPO training (--size --method --workers --local-steps --steps\n\
                   --task math|code --lr --seed --eval-every --out)\n\
           grail   deployment simulation (--size --windows --miners --steps-per-window)\n\
           sync    PULSESync demo (--size --steps)\n\
           info    print a model manifest\n"
    );
}

fn load_rt(args: &Args) -> Result<ModelRuntime> {
    let size = args.str_or("size", "tiny");
    ModelRuntime::load(&artifacts_dir(), &size, &[])
}

fn cmd_info(args: &Args) -> Result<()> {
    let rt = load_rt(args)?;
    let m = &rt.manifest;
    println!("model     : {}", m.name);
    println!("platform  : {}", rt.platform());
    println!("params    : {}", m.n_params);
    println!(
        "dims      : d_model={} layers={} heads={} vocab={} seq={} (P={} G={}) batch={}",
        m.dims.d_model,
        m.dims.n_layers,
        m.dims.n_heads,
        m.dims.vocab,
        m.dims.seq,
        m.dims.prompt_len,
        m.dims.gen_len,
        m.dims.batch
    );
    println!("tensors   : {}", m.layout.len());
    println!("artifacts : {:?}", m.artifacts.keys().collect::<Vec<_>>());
    println!(
        "bf16 ckpt : {}",
        pulse::util::fmt_bytes(pulse::baselines::full_checkpoint_bytes(m.n_params as u64))
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let rt = load_rt(args)?;
    let method = Method::parse(&args.str_or("method", "single"))?;
    let task = match args.str_or("task", "math").as_str() {
        "code" => TaskKind::Code,
        _ => TaskKind::Math,
    };
    let lr = args.f64_or("lr", 3e-6) as f32;
    let cfg = TrainConfig {
        method,
        workers: args.usize_or("workers", 4),
        local_steps: args.usize_or("local-steps", 8),
        steps: args.usize_or("steps", 64),
        rollout_interval: args.usize_or("rollout-interval", 1),
        adam: AdamConfig { lr, ..AdamConfig::default() },
        grpo: GrpoConfig { group: args.usize_or("group", 8), ..Default::default() },
        seed: args.u64_or("seed", 0),
        eval_every: args.usize_or("eval-every", 16),
        n_eval: args.usize_or("n-eval", 64),
        sparsity_ks: args.usize_list_or("ks", &[1, 8, 16, 32]),
        task,
        capture_every: args.usize_or("capture-every", 0),
    };
    println!(
        "[pulse train] size={} method={} workers={} H={} steps={} lr={}",
        rt.manifest.name,
        method.name(),
        cfg.workers,
        cfg.local_steps,
        cfg.steps,
        lr
    );
    let t0 = pulse::util::Stopwatch::start();
    let res = coordinator::train(&rt, &cfg)?;
    let out = args.str_or("out", "");
    if method == Method::Single {
        for s in &res.steps {
            let s1 = s.sparsity.iter().find(|(k, _)| *k == 1).map(|(_, v)| *v);
            println!(
                "step {:>4}  loss {:+.5}  reward {:.3}  correct {:.3}  grad_density {:.3}  S1 {}  pass@1 {}",
                s.step,
                s.loss,
                s.mean_reward,
                s.correct_rate,
                s.grad_density,
                s1.map(|v| format!("{:.4}", v)).unwrap_or_else(|| "-".into()),
                s.pass_at_1.map(|v| format!("{:.3}", v)).unwrap_or_else(|| "-".into()),
            );
        }
        if !out.is_empty() {
            let mut w = metrics::CsvWriter::create(
                std::path::Path::new(&out),
                &["step", "loss", "reward", "correct", "grad_density", "s1", "pass1"],
            )?;
            for s in &res.steps {
                let s1 = s
                    .sparsity
                    .iter()
                    .find(|(k, _)| *k == 1)
                    .map(|(_, v)| *v)
                    .unwrap_or(f64::NAN);
                w.row(&[
                    s.step.to_string(),
                    format!("{}", s.loss),
                    format!("{}", s.mean_reward),
                    format!("{}", s.correct_rate),
                    format!("{}", s.grad_density),
                    format!("{}", s1),
                    s.pass_at_1.map(|v| v.to_string()).unwrap_or_default(),
                ])?;
            }
            println!("wrote {}", out);
        }
    } else {
        for r in &res.rounds {
            let comm0 = r.comm.first();
            println!(
                "round {:>3} (step {:>4})  loss {:+.5}  reward {:.3}  comm_sparsity {:.4}  payload {}  pass@1 {}",
                r.round,
                r.global_step,
                r.mean_loss,
                r.mean_reward,
                comm0.map(|c| c.comm_sparsity).unwrap_or(0.0),
                comm0
                    .map(|c| pulse::util::fmt_bytes(c.encoded_payload_bytes))
                    .unwrap_or_else(|| "-".into()),
                r.pass_at_1.map(|v| format!("{:.3}", v)).unwrap_or_else(|| "-".into()),
            );
        }
    }
    println!(
        "[pulse train] done in {:.1}s  final pass@1 = {:.3}",
        t0.secs(),
        res.final_pass_at_1
    );
    Ok(())
}

fn cmd_grail(args: &Args) -> Result<()> {
    let rt = load_rt(args)?;
    let task = pulse::rl::tasks::MathTask::default();
    let master = coordinator::init_master(&rt, args.u64_or("seed", 0))?;
    let cfg = pulse::grail::GrailConfig {
        n_miners: args.usize_or("miners", 2),
        steps_per_window: args.usize_or("steps-per-window", 4),
        batches_per_miner: args.usize_or("batches-per-miner", 1),
        anchor_interval: args.u64_or("anchor-interval", 50),
        proof_tolerance: 2,
        n_eval: args.usize_or("n-eval", 64),
    };
    let mut sim = pulse::grail::GrailSim::new(
        &rt,
        &task,
        cfg,
        master,
        AdamConfig::post_training(),
        args.u64_or("seed", 0),
    )?;
    let windows = args.usize_or("windows", 5);
    println!(
        "[pulse grail] size={} miners={} windows={}",
        rt.manifest.name, cfg.n_miners, windows
    );
    for w in 0..windows as u64 {
        let st = sim.run_window(w)?;
        println!(
            "window {:>3}  pass@1 {:.3}  upload {:>10}  (full would be {:>10})  verified {}/{}  replay_age {:.2}",
            st.window,
            st.pass_at_1,
            pulse::util::fmt_bytes(st.upload_bytes),
            pulse::util::fmt_bytes(st.full_checkpoint_bytes),
            st.verified,
            st.verified + st.rejected,
            st.replay_mean_age
        );
    }
    Ok(())
}

fn cmd_sync(args: &Args) -> Result<()> {
    use pulse::pulse::sync::{Consumer, Publisher};
    let rt = load_rt(args)?;
    let mut master = coordinator::init_master(&rt, 1)?;
    let store = pulse::storage::ObjectStore::temp("cli_sync")?;
    let mut view = Vec::new();
    pulse::bf16::cast_slice_par(&master, &mut view);
    let mut publisher =
        Publisher::new(store.clone(), "sync", rt.manifest.layout.clone(), view, 10)?;
    let mut consumer = Consumer::new(store, "sync", rt.manifest.layout.clone());
    consumer.synchronize()?;
    let mut rng = pulse::util::rng::Rng::new(2);
    let steps = args.usize_or("steps", 10);
    println!("[pulse sync] size={} steps={}", rt.manifest.name, steps);
    for step in 1..=steps as u64 {
        // Adam-scale drift on the master
        for x in master.iter_mut() {
            *x += 3e-6 * if rng.f64() < 0.5 { -1.0 } else { 1.0 };
        }
        let mut view = Vec::new();
        pulse::bf16::cast_slice_par(&master, &mut view);
        let ps = publisher.publish(step, &view)?;
        let cs = consumer.synchronize()?;
        println!(
            "step {:>3}  sparsity {:.4}  patch {:>9}  (full {:>9})  path {:?}  verified {}",
            step,
            ps.sparsity,
            pulse::util::fmt_bytes(ps.patch_bytes),
            pulse::util::fmt_bytes((rt.manifest.n_params * 2) as u64),
            cs.path,
            cs.verified
        );
        assert_eq!(consumer.weights.as_ref().unwrap(), publisher.current_weights());
    }
    println!("[pulse sync] bit-identical reconstruction verified at every step");
    Ok(())
}
