//! Observability for the sync plane: trace spans, a bounded flight
//! recorder, and log-bucket latency histograms (ISSUE 10).
//!
//! The counters in [`crate::net::transport::TransportCounters`] say how
//! *often* things happened; this module says **where a patch's time
//! goes** between [`crate::pulse::sync::Publisher::publish`] and a
//! leaf's apply, and lets `paper obs <addr>` ask a live node mid-run.
//!
//! # Span model
//!
//! A [`SpanEvent`] is a fixed-size record keyed by
//! `(generation, step, shard)` with a [`Stage`] tag marking one
//! transition of a patch's life: publish → relay stage →
//! coalesce/evict → NACK/serve/escalate → leaf apply (plus slow-path
//! catch-up and repair give-up). Events carry a microsecond timestamp
//! drawn through an existing time seam — the wall
//! [`crate::util::Stopwatch`] on real sockets, the virtual
//! [`crate::sim`] clock inside the simulator — so the same
//! reconstruction ([`reconstruct`]) and the same deterministic
//! [`trace_hash`] work on both.
//!
//! # Flight recorder
//!
//! [`FlightRecorder`] is a fixed-capacity ring of [`SpanEvent`]s: the
//! buffer is allocated once at construction and recording never
//! allocates, so it is safe on the relay/transport hot paths. When the
//! ring wraps, the oldest events are overwritten and counted in
//! `dropped`. The process-global recorder ([`Obs::global`]) dumps JSON
//! on demand (`OBS_SNAP` / `paper obs`) and on incident paths —
//! repair `gave_up`, escalation failure — via [`Obs::dump_incident`]
//! (written only when `PULSE_OBS_DUMP_DIR` is set, so tests stay
//! quiet).
//!
//! # Histograms
//!
//! [`Histogram`] buckets microsecond latencies by power of two
//! (40 buckets ≈ 0 µs .. 12 days) with lock-free atomic counts, and
//! reports p50/p99/p999 as bucket upper bounds. The process hub keeps
//! one per [`HistKind`]: NACK repair, slow-path catch-up, store RPC,
//! and end-to-end step latency. [`Obs::hist_names`] is the canonical
//! registry the `counter-csv-drift` lint checks against
//! `ObsExport::write_csv` (see `coordinator/metrics.rs`), so a
//! histogram added here must reach the CSV exporter or the tree fails
//! `paper lint`.

use crate::util::json::Json;
use crate::util::sync::LockExt;
use anyhow::{Context, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// One transition in a patch's publish→apply life. The discriminants
/// are stable wire/hash values: changing one changes every stored
/// trace hash.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Stage {
    /// Publisher committed a shard frame (detail = frame bytes).
    Publish = 1,
    /// Relay staged a frame for fan-out (detail = stage depth).
    RelayStage = 2,
    /// An enqueue superseded an older queued frame (detail = queue len).
    Coalesce = 3,
    /// A full queue dropped a frame (detail = queue depth).
    Evict = 4,
    /// Subscriber sent a repair NACK (detail = attempt number).
    NackSent = 5,
    /// Relay retransmitted a staged frame for a NACK (detail = bytes).
    NackServe = 6,
    /// Relay escalated a NACK upstream (detail = riders).
    Escalate = 7,
    /// Subscriber received the repair retransmit (detail = bytes).
    Retransmit = 8,
    /// NACK answered unserviceable: slot evicted along the whole path.
    NackMiss = 9,
    /// Consumer fell back to the anchor slow path (detail = anchor step).
    CatchUp = 10,
    /// Leaf applied the step (detail = bytes downloaded).
    Apply = 11,
    /// Repair retry budget drained without a retransmit.
    GaveUp = 12,
}

impl Stage {
    /// Every stage, in publish→apply pipeline order (table order for
    /// `paper trace` output).
    pub const ALL: [Stage; 12] = [
        Stage::Publish,
        Stage::RelayStage,
        Stage::Coalesce,
        Stage::Evict,
        Stage::NackSent,
        Stage::NackServe,
        Stage::Escalate,
        Stage::Retransmit,
        Stage::NackMiss,
        Stage::CatchUp,
        Stage::Apply,
        Stage::GaveUp,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Stage::Publish => "publish",
            Stage::RelayStage => "relay_stage",
            Stage::Coalesce => "coalesce",
            Stage::Evict => "evict",
            Stage::NackSent => "nack_sent",
            Stage::NackServe => "nack_serve",
            Stage::Escalate => "escalate",
            Stage::Retransmit => "retransmit",
            Stage::NackMiss => "nack_miss",
            Stage::CatchUp => "catch_up",
            Stage::Apply => "apply",
            Stage::GaveUp => "gave_up",
        }
    }

    pub fn from_u8(v: u8) -> Option<Stage> {
        Stage::ALL.iter().copied().find(|s| *s as u8 == v)
    }
}

/// One fixed-size trace event. `Copy` so ring writes are plain stores
/// — the recorder hot path never allocates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SpanEvent {
    /// Microseconds since the recorder's epoch (process start on the
    /// wall seam, virtual t=0 inside the simulator).
    pub t_us: u64,
    pub generation: u64,
    pub step: u64,
    pub shard: u32,
    /// `Stage` discriminant (u8 so the event stays 40 bytes).
    pub stage: u8,
    /// Stage-specific detail (bytes, depth, attempt, …).
    pub detail: u64,
}

impl SpanEvent {
    pub fn stage(&self) -> Option<Stage> {
        Stage::from_u8(self.stage)
    }

    fn to_json(self) -> Json {
        let mut j = Json::obj();
        j.set("t_us", self.t_us.into())
            .set("gen", self.generation.into())
            .set("step", self.step.into())
            .set("shard", (self.shard as u64).into())
            .set("stage", self.stage().map(Stage::name).unwrap_or("?").into())
            .set("detail", self.detail.into());
        j
    }

    fn from_json(j: &Json) -> Result<SpanEvent> {
        let stage_name = j.req_str("stage")?;
        let stage = Stage::ALL
            .iter()
            .copied()
            .find(|s| s.name() == stage_name)
            .ok_or_else(|| anyhow::anyhow!("unknown stage '{}'", stage_name))?;
        Ok(SpanEvent {
            t_us: j.req_f64("t_us")? as u64,
            generation: j.req_f64("gen")? as u64,
            step: j.req_f64("step")? as u64,
            shard: j.req_f64("shard")? as u32,
            stage: stage as u8,
            detail: j.req_f64("detail")? as u64,
        })
    }
}

/// Default ring capacity of the process-global recorder.
pub const DEFAULT_RING: usize = 8192;

struct Ring {
    buf: Vec<SpanEvent>,
    /// Next write slot (wraps at capacity).
    next: usize,
    /// Events ever recorded (total - capacity = overwritten).
    total: u64,
}

/// Fixed-capacity ring of [`SpanEvent`]s. The buffer is preallocated
/// in [`FlightRecorder::new`]; [`FlightRecorder::record`] is a mutex
/// lock plus one array store — no allocation, no channel, bounded by
/// construction.
pub struct FlightRecorder {
    ring: Mutex<Ring>,
    capacity: usize,
}

impl FlightRecorder {
    pub fn new(capacity: usize) -> FlightRecorder {
        let capacity = capacity.max(1);
        FlightRecorder {
            ring: Mutex::new(Ring {
                buf: vec![SpanEvent::default(); capacity],
                next: 0,
                total: 0,
            }),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Record one event (overwrites the oldest once full).
    pub fn record(&self, ev: SpanEvent) {
        let mut r = self.ring.plock();
        let slot = r.next;
        r.buf[slot] = ev;
        r.next = (slot + 1) % self.capacity;
        r.total += 1;
    }

    /// Events ever recorded (including overwritten ones).
    pub fn total(&self) -> u64 {
        self.ring.plock().total
    }

    /// Events lost to ring wrap.
    pub fn dropped(&self) -> u64 {
        let r = self.ring.plock();
        r.total.saturating_sub(self.capacity as u64)
    }

    /// Retained events, oldest first.
    pub fn snapshot(&self) -> Vec<SpanEvent> {
        let r = self.ring.plock();
        let kept = (r.total as usize).min(self.capacity);
        let mut out = Vec::with_capacity(kept);
        // oldest retained event sits at `next` once the ring has wrapped
        let start = if r.total as usize > self.capacity { r.next } else { 0 };
        for i in 0..kept {
            out.push(r.buf[(start + i) % self.capacity]);
        }
        out
    }

    pub fn clear(&self) {
        let mut r = self.ring.plock();
        r.next = 0;
        r.total = 0;
    }

    pub fn to_json(&self) -> Json {
        let events: Vec<Json> = self.snapshot().into_iter().map(SpanEvent::to_json).collect();
        let mut j = Json::obj();
        j.set("capacity", self.capacity.into())
            .set("total", self.total().into())
            .set("dropped", self.dropped().into())
            .set("events", Json::Arr(events));
        j
    }
}

/// Power-of-two microsecond buckets: bucket `i` holds `[2^i, 2^(i+1))`
/// (0 µs lands in bucket 0). 40 buckets cover ~12 days.
pub const HIST_BUCKETS: usize = 40;

/// Lock-free log-bucket latency histogram. Percentiles are reported as
/// the upper bound of the bucket the rank lands in — at most 2x the
/// true latency, which is all a p999 over a long-tailed repair path
/// needs.
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    fn bucket_of(us: u64) -> usize {
        // floor(log2(us)) with 0 and 1 both in bucket 0; the tail
        // collapses into the last bucket
        ((63 - (us | 1).leading_zeros()) as usize).min(HIST_BUCKETS - 1)
    }

    /// Upper bound (inclusive) of bucket `i`, in microseconds.
    fn bucket_hi(i: usize) -> u64 {
        (1u64 << (i + 1)) - 1
    }

    pub fn record_us(&self, us: u64) {
        self.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Latency at quantile `q` in `(0, 1]`, as the containing bucket's
    /// upper bound (0 when empty).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return Self::bucket_hi(i);
            }
        }
        self.max_us()
    }

    pub fn p50_us(&self) -> u64 {
        self.quantile_us(0.50)
    }

    pub fn p99_us(&self) -> u64 {
        self.quantile_us(0.99)
    }

    pub fn p999_us(&self) -> u64 {
        self.quantile_us(0.999)
    }

    pub fn clear(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_us.store(0, Ordering::Relaxed);
        self.max_us.store(0, Ordering::Relaxed);
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("count", self.count().into())
            .set("mean_us", self.mean_us().into())
            .set("p50_us", self.p50_us().into())
            .set("p99_us", self.p99_us().into())
            .set("p999_us", self.p999_us().into())
            .set("max_us", self.max_us().into());
        j
    }
}

/// The latency surfaces the hub tracks, index order matching
/// [`Obs::hist_names`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HistKind {
    /// NACK sent → retransmit applied (relay repair seam).
    NackRepair = 0,
    /// Slow-path anchor restore + chain replay.
    CatchUp = 1,
    /// One store-plane RPC round trip.
    StoreRpc = 2,
    /// `synchronize()` end to end (excluding up-to-date no-ops).
    E2eStep = 3,
}

/// Process-wide observability hub: one flight recorder + the standard
/// latency histograms, behind a single enable flag checked with one
/// relaxed atomic load on every hot-path call.
pub struct Obs {
    enabled: AtomicBool,
    pub recorder: FlightRecorder,
    hists: [Histogram; 4],
    incident_seq: AtomicU64,
}

static GLOBAL: OnceLock<Obs> = OnceLock::new();

impl Obs {
    fn new() -> Obs {
        Obs {
            enabled: AtomicBool::new(true),
            recorder: FlightRecorder::new(DEFAULT_RING),
            hists: std::array::from_fn(|_| Histogram::new()),
            incident_seq: AtomicU64::new(0),
        }
    }

    /// The process-global hub (created on first use, enabled by
    /// default).
    pub fn global() -> &'static Obs {
        GLOBAL.get_or_init(Obs::new)
    }

    /// Canonical histogram registry, index order matching [`HistKind`].
    /// The `counter-csv-drift` lint requires every name here to appear
    /// in `ObsExport::write_csv` (`coordinator/metrics.rs`) — add a
    /// histogram without exporting it and `paper lint` fails.
    pub fn hist_names() -> [&'static str; 4] {
        ["nack_repair_us", "catch_up_us", "store_rpc_us", "e2e_step_us"]
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Microseconds on the process wall anchor — the same
    /// [`crate::sim::clock::Clock::Wall`] reading the relay's
    /// escalation windows use, so spans stamped here and spans stamped
    /// from a relay's clock share one epoch.
    pub fn now_us(&self) -> u64 {
        crate::sim::clock::Clock::wall().now().as_micros() as u64
    }

    /// Record a span stamped with the hub's wall clock.
    pub fn span(&self, stage: Stage, generation: u64, step: u64, shard: u32, detail: u64) {
        if !self.enabled() {
            return;
        }
        self.span_at(self.now_us(), stage, generation, step, shard, detail);
    }

    /// Record a span with an explicit timestamp (relay [`crate::sim`]
    /// virtual clocks draw `t_us` from their own seam).
    pub fn span_at(
        &self,
        t_us: u64,
        stage: Stage,
        generation: u64,
        step: u64,
        shard: u32,
        detail: u64,
    ) {
        if !self.enabled() {
            return;
        }
        self.recorder.record(SpanEvent {
            t_us,
            generation,
            step,
            shard,
            stage: stage as u8,
            detail,
        });
    }

    pub fn hist(&self, kind: HistKind) -> &Histogram {
        &self.hists[kind as usize]
    }

    pub fn hist_named(&self, name: &str) -> Option<&Histogram> {
        let i = Self::hist_names().iter().position(|n| *n == name)?;
        Some(&self.hists[i])
    }

    /// Record one latency sample (no-op while disabled, so the
    /// recorder-off bench rows measure the true cost of the flag).
    pub fn record_hist(&self, kind: HistKind, us: u64) {
        if !self.enabled() {
            return;
        }
        self.hists[kind as usize].record_us(us);
    }

    /// Convenience for seconds-valued timers ([`crate::util::Stopwatch::secs`]).
    pub fn record_hist_secs(&self, kind: HistKind, secs: f64) {
        self.record_hist(kind, (secs * 1e6) as u64);
    }

    /// Reset recorder + histograms (bench and test isolation).
    pub fn clear(&self) {
        self.recorder.clear();
        for h in &self.hists {
            h.clear();
        }
    }

    /// Full hub snapshot: histograms always; recorder events only when
    /// `with_events` (the `OBS_SNAP` flags bit 0).
    pub fn snapshot_json(&self, with_events: bool) -> Json {
        let mut hists = Json::obj();
        for (i, name) in Self::hist_names().iter().enumerate() {
            hists.set(name, self.hists[i].to_json());
        }
        let mut j = Json::obj();
        j.set("enabled", self.enabled().into())
            .set("now_us", self.now_us().into())
            .set("histograms", hists);
        if with_events {
            j.set("recorder", self.recorder.to_json());
        } else {
            let mut r = Json::obj();
            r.set("capacity", self.recorder.capacity().into())
                .set("total", self.recorder.total().into())
                .set("dropped", self.recorder.dropped().into());
            j.set("recorder", r);
        }
        j
    }

    /// Dump the recorder on an incident path (repair `gave_up`,
    /// escalation failure). Writes
    /// `$PULSE_OBS_DUMP_DIR/obs_incident_<seq>_<reason>.json`; a no-op
    /// when the env var is unset so hot paths and tests never touch
    /// the filesystem by surprise. Returns the path written, if any.
    pub fn dump_incident(&self, reason: &str) -> Option<std::path::PathBuf> {
        let dir = std::env::var("PULSE_OBS_DUMP_DIR").ok()?;
        if dir.is_empty() {
            return None;
        }
        let seq = self.incident_seq.fetch_add(1, Ordering::Relaxed);
        let safe: String = reason
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
            .take(48)
            .collect();
        let path = std::path::Path::new(&dir).join(format!("obs_incident_{:04}_{}.json", seq, safe));
        let mut j = self.snapshot_json(true);
        j.set("reason", reason.into());
        std::fs::create_dir_all(&dir).ok()?;
        std::fs::write(&path, j.to_pretty()).ok()?;
        Some(path)
    }
}

/// Record a span on the process-global hub (wall timestamps). The
/// instrumentation entry point for the socket plane.
pub fn span(stage: Stage, generation: u64, step: u64, shard: u32, detail: u64) {
    Obs::global().span(stage, generation, step, shard, detail);
}

/// Record a span on the process-global hub at an explicit time
/// (virtual-clock call sites: the relay under `Clock::Virtual`).
pub fn span_at(t_us: u64, stage: Stage, generation: u64, step: u64, shard: u32, detail: u64) {
    Obs::global().span_at(t_us, stage, generation, step, shard, detail);
}

/// Record one latency sample on the process-global hub.
pub fn hist(kind: HistKind, us: u64) {
    Obs::global().record_hist(kind, us);
}

/// Record one seconds-valued latency sample on the process-global hub.
pub fn hist_secs(kind: HistKind, secs: f64) {
    Obs::global().record_hist_secs(kind, secs);
}

// ---------------------------------------------------------------------
// Trace reconstruction
// ---------------------------------------------------------------------

/// Per-stage latency summary over every `(step, shard)` timeline:
/// offsets are measured from that key's first `publish` event.
#[derive(Debug, Clone)]
pub struct StageRow {
    pub stage: Stage,
    /// Events of this stage seen across all timelines.
    pub count: usize,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
}

/// The cross-hop timeline reconstruction `paper trace` prints.
#[derive(Debug, Clone, Default)]
pub struct TraceReport {
    /// Distinct `(step, shard)` keys seen.
    pub timelines: usize,
    /// Timelines with both a `publish` and an `apply` event.
    pub complete: usize,
    /// Keys missing either endpoint.
    pub incomplete: Vec<(u64, u32)>,
    pub rows: Vec<StageRow>,
}

impl TraceReport {
    pub fn is_complete(&self) -> bool {
        self.timelines > 0 && self.complete == self.timelines
    }
}

fn pct_sorted(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Reconstruct per-`(step, shard)` timelines from collected recorder
/// events (any order, any number of recorders merged) into a
/// per-stage breakdown. Offsets are exact (computed from raw events,
/// not histogram buckets). Spans are *keyed* by
/// `(generation, step, shard)` but timelines group on `(step, shard)`:
/// mid-stream hops cannot know the publisher generation, and a
/// re-published step after a lineage rewind is one timeline.
pub fn reconstruct(events: &[SpanEvent]) -> TraceReport {
    use std::collections::BTreeMap;
    let mut by_key: BTreeMap<(u64, u32), Vec<&SpanEvent>> = BTreeMap::new();
    for ev in events {
        by_key.entry((ev.step, ev.shard)).or_default().push(ev);
    }
    let mut report = TraceReport { timelines: by_key.len(), ..Default::default() };
    let mut offsets: BTreeMap<u8, Vec<u64>> = BTreeMap::new();
    for (key, evs) in &by_key {
        let t0 = evs
            .iter()
            .filter(|e| e.stage == Stage::Publish as u8)
            .map(|e| e.t_us)
            .min();
        let applied = evs.iter().any(|e| e.stage == Stage::Apply as u8);
        match t0 {
            Some(t0) if applied => {
                report.complete += 1;
                for e in evs {
                    offsets.entry(e.stage).or_default().push(e.t_us.saturating_sub(t0));
                }
            }
            _ => report.incomplete.push(*key),
        }
    }
    for stage in Stage::ALL {
        if let Some(v) = offsets.get_mut(&(stage as u8)) {
            v.sort_unstable();
            report.rows.push(StageRow {
                stage,
                count: v.len(),
                p50_us: pct_sorted(v, 0.50),
                p99_us: pct_sorted(v, 0.99),
                max_us: *v.last().unwrap(),
            });
        }
    }
    report
}

/// Deterministic FNV-1a hash over a span stream. Inside the simulator
/// the same seed and config must reproduce this bit-identically across
/// replays; any reordering, timestamp drift, or dropped span changes
/// it.
pub fn trace_hash(events: &[SpanEvent]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for ev in events {
        h = fold_span(h, ev);
    }
    h
}

/// One [`trace_hash`] folding step — lets the simulator hash its span
/// stream incrementally (bounded memory at 100k leaves) and still agree
/// with `trace_hash` over the same events.
pub fn fold_span(mut h: u64, ev: &SpanEvent) -> u64 {
    let mut mix = |v: u64| {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    mix(ev.t_us);
    mix(ev.generation);
    mix(ev.step);
    mix(ev.shard as u64);
    mix(ev.stage as u64);
    mix(ev.detail);
    h
}

/// Parse recorder events back out of a snapshot/dump JSON (the inverse
/// of [`FlightRecorder::to_json`], used by `paper trace` to merge
/// dumps collected from several processes).
pub fn events_from_json(j: &Json) -> Result<Vec<SpanEvent>> {
    let rec = j.get("recorder").unwrap_or(j);
    let events = rec
        .get("events")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("snapshot has no recorder events"))?;
    events.iter().map(SpanEvent::from_json).collect()
}

// ---------------------------------------------------------------------
// Live snapshot wire client (OBS_SNAP → OBS_REPLY)
// ---------------------------------------------------------------------

/// `OBS_SNAP` flags bit 0: include recorder events in the reply.
pub const SNAP_WITH_EVENTS: u64 = 1;

/// Fetch a live metric+recorder snapshot from a node serving the
/// `OBS_SNAP` frame (`Relay`, `RelayNode`, `StoreServer`,
/// `ControlPlane`). `addr` is `host:port` or a bare port (localhost).
pub fn fetch_snapshot(addr: &str, flags: u64) -> Result<Json> {
    use crate::net::tcp::{self, kind, Frame};
    let addr = if addr.contains(':') {
        addr.to_string()
    } else {
        format!("127.0.0.1:{}", addr.parse::<u16>().context("addr must be host:port or port")?)
    };
    let mut stream = std::net::TcpStream::connect(&addr)
        .with_context(|| format!("connecting to {}", addr))?;
    stream.set_read_timeout(Some(std::time::Duration::from_secs(5)))?;
    tcp::write_frame(&mut stream, &Frame { kind: kind::OBS_SNAP, payload: tcp::obs_snap_payload(flags) })?;
    loop {
        let reply = tcp::read_frame(&mut stream)?;
        match reply.kind {
            kind::OBS_REPLY => {
                let text = tcp::parse_obs_reply(&reply.payload)?;
                let _ = tcp::write_frame(&mut stream, &Frame { kind: kind::CLOSE, payload: vec![] });
                return Json::parse(&text);
            }
            // relay sockets push staged traffic to every subscriber;
            // skip frames until our reply arrives
            _ => continue,
        }
    }
}

/// Build the standard `OBS_REPLY` body a server sends: the process
/// hub's snapshot plus a role tag and role-specific counters.
pub fn snapshot_reply(role: &str, flags: u64, extra: Json) -> Json {
    let mut j = Obs::global().snapshot_json(flags & SNAP_WITH_EVENTS != 0);
    j.set("role", role.into()).set("counters", extra);
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t: u64, step: u64, shard: u32, stage: Stage) -> SpanEvent {
        SpanEvent { t_us: t, generation: 0, step, shard, stage: stage as u8, detail: 0 }
    }

    #[test]
    fn ring_wraps_and_counts_drops() {
        let r = FlightRecorder::new(4);
        for i in 0..10u64 {
            r.record(ev(i, i, 0, Stage::Publish));
        }
        assert_eq!(r.total(), 10);
        assert_eq!(r.dropped(), 6);
        let snap = r.snapshot();
        assert_eq!(snap.len(), 4);
        // oldest-first, newest retained events are 6..=9
        assert_eq!(snap.iter().map(|e| e.t_us).collect::<Vec<_>>(), vec![6, 7, 8, 9]);
    }

    #[test]
    fn ring_snapshot_before_wrap_is_in_order() {
        let r = FlightRecorder::new(8);
        for i in 0..3u64 {
            r.record(ev(i * 10, i, 0, Stage::Apply));
        }
        assert_eq!(r.dropped(), 0);
        assert_eq!(r.snapshot().iter().map(|e| e.t_us).collect::<Vec<_>>(), vec![0, 10, 20]);
    }

    #[test]
    fn histogram_percentiles_bracket_samples() {
        let h = Histogram::new();
        for us in [1u64, 2, 3, 100, 1000, 10_000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.max_us(), 10_000);
        // bucket upper bounds: within 2x above the true value, never below
        assert!(h.p50_us() >= 3 && h.p50_us() <= 200);
        assert!(h.p99_us() >= 10_000 && h.p99_us() <= 20_000);
        assert!(h.p999_us() >= h.p99_us());
        assert_eq!(Histogram::new().p99_us(), 0);
    }

    #[test]
    fn histogram_bucket_edges() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 0);
        assert_eq!(Histogram::bucket_of(2), 1);
        assert_eq!(Histogram::bucket_of(3), 1);
        assert_eq!(Histogram::bucket_of(4), 2);
        assert_eq!(Histogram::bucket_of(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn reconstruct_reports_completeness_and_offsets() {
        let mut evs = vec![
            ev(100, 1, 0, Stage::Publish),
            ev(150, 1, 0, Stage::RelayStage),
            ev(300, 1, 0, Stage::Apply),
            ev(200, 2, 0, Stage::Publish),
            ev(420, 2, 0, Stage::Apply),
            // step 3 never applies
            ev(500, 3, 0, Stage::Publish),
        ];
        // order must not matter
        evs.reverse();
        let r = reconstruct(&evs);
        assert_eq!(r.timelines, 3);
        assert_eq!(r.complete, 2);
        assert_eq!(r.incomplete, vec![(3, 0)]);
        assert!(!r.is_complete());
        let apply = r.rows.iter().find(|row| row.stage == Stage::Apply).unwrap();
        assert_eq!(apply.count, 2);
        assert_eq!(apply.max_us, 220);
        assert_eq!(apply.p50_us, 200);
        let publish = r.rows.iter().find(|row| row.stage == Stage::Publish).unwrap();
        assert_eq!(publish.max_us, 0);
    }

    #[test]
    fn trace_hash_is_deterministic_and_sensitive() {
        let a = vec![ev(1, 1, 0, Stage::Publish), ev(2, 1, 0, Stage::Apply)];
        let b = a.clone();
        assert_eq!(trace_hash(&a), trace_hash(&b));
        let mut c = a.clone();
        c[1].t_us = 3;
        assert_ne!(trace_hash(&a), trace_hash(&c));
        let mut d = a.clone();
        d.swap(0, 1);
        assert_ne!(trace_hash(&a), trace_hash(&d));
        assert_ne!(trace_hash(&a), trace_hash(&a[..1]));
    }

    #[test]
    fn snapshot_json_roundtrips_events() {
        let r = FlightRecorder::new(16);
        r.record(SpanEvent {
            t_us: 42,
            generation: 3,
            step: 7,
            shard: 2,
            stage: Stage::NackServe as u8,
            detail: 999,
        });
        let j = r.to_json();
        let text = j.to_pretty();
        let parsed = Json::parse(&text).unwrap();
        let mut wrapper = Json::obj();
        wrapper.set("recorder", parsed);
        let evs = events_from_json(&wrapper).unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].t_us, 42);
        assert_eq!(evs[0].generation, 3);
        assert_eq!(evs[0].step, 7);
        assert_eq!(evs[0].shard, 2);
        assert_eq!(evs[0].stage(), Some(Stage::NackServe));
        assert_eq!(evs[0].detail, 999);
        assert_eq!(trace_hash(&evs), trace_hash(&r.snapshot()));
    }

    #[test]
    fn hub_enable_flag_gates_recording() {
        // a private hub, not the global one, so tests stay independent
        let hub = Obs::new();
        hub.set_enabled(false);
        hub.span(Stage::Publish, 0, 1, 0, 0);
        hub.record_hist(HistKind::E2eStep, 10);
        assert_eq!(hub.recorder.total(), 0);
        assert_eq!(hub.hist(HistKind::E2eStep).count(), 0);
        hub.set_enabled(true);
        hub.span(Stage::Publish, 0, 1, 0, 0);
        hub.record_hist(HistKind::E2eStep, 10);
        assert_eq!(hub.recorder.total(), 1);
        assert_eq!(hub.hist(HistKind::E2eStep).count(), 1);
        let snap = hub.snapshot_json(true);
        assert_eq!(snap.get("histograms").unwrap().get("e2e_step_us").unwrap().req_f64("count").unwrap(), 1.0);
        assert_eq!(
            snap.get("recorder").unwrap().get("events").unwrap().as_arr().unwrap().len(),
            1
        );
    }

    #[test]
    fn hist_names_match_hist_kinds() {
        let names = Obs::hist_names();
        assert_eq!(names.len(), 4);
        for (kind, name) in [
            (HistKind::NackRepair, "nack_repair_us"),
            (HistKind::CatchUp, "catch_up_us"),
            (HistKind::StoreRpc, "store_rpc_us"),
            (HistKind::E2eStep, "e2e_step_us"),
        ] {
            assert_eq!(names[kind as usize], name);
            let hub = Obs::new();
            hub.record_hist(kind, 5);
            assert_eq!(hub.hist_named(name).unwrap().count(), 1);
        }
        assert!(Obs::new().hist_named("nope").is_none());
    }

    #[test]
    fn incident_dump_writes_only_when_dir_set() {
        let hub = Obs::new();
        // without the env var: silent no-op
        std::env::remove_var("PULSE_OBS_DUMP_DIR");
        assert!(hub.dump_incident("gave_up").is_none());
        let dir = std::env::temp_dir().join(format!("obs_dump_test_{}", std::process::id()));
        std::env::set_var("PULSE_OBS_DUMP_DIR", &dir);
        hub.span(Stage::GaveUp, 0, 9, 1, 0);
        let path = hub.dump_incident("gave_up: step 9 shard 1").unwrap();
        std::env::remove_var("PULSE_OBS_DUMP_DIR");
        let j = Json::parse_file(&path).unwrap();
        assert_eq!(j.req_str("reason").unwrap(), "gave_up: step 9 shard 1");
        let evs = events_from_json(&j).unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].stage(), Some(Stage::GaveUp));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stage_names_roundtrip() {
        for s in Stage::ALL {
            assert_eq!(Stage::from_u8(s as u8), Some(s));
            assert!(!s.name().is_empty());
        }
        assert_eq!(Stage::from_u8(0), None);
        assert_eq!(Stage::from_u8(200), None);
    }
}
