//! Sparsity metering (paper §3, Def. A.1/A.2): maintains a ring of BF16
//! snapshots of the master weights and reports k-step compute-view
//! sparsity S_k = |{i : cast(θ_t) == cast(θ_{t+k})}| / d, bitwise.

use crate::bf16;

pub struct SparsityMeter {
    /// Comparison distances (paper uses k ∈ {1, 8, 16, 32}).
    pub ks: Vec<usize>,
    ring: Vec<Vec<u16>>, // ring[t % cap]
    cap: usize,
    t: usize, // number of snapshots recorded
    scratch: Vec<u16>,
}

impl SparsityMeter {
    pub fn new(ks: Vec<usize>) -> SparsityMeter {
        let cap = ks.iter().copied().max().unwrap_or(1) + 1;
        SparsityMeter { ks, ring: Vec::new(), cap, t: 0, scratch: Vec::new() }
    }

    /// Record the BF16 view of `master` after an optimizer step and
    /// return (k, sparsity) for every k with enough history.
    pub fn record(&mut self, master: &[f32]) -> Vec<(usize, f64)> {
        bf16::cast_slice_par(master, &mut self.scratch);
        let snapshot = self.scratch.clone();
        if self.ring.len() < self.cap {
            self.ring.push(snapshot);
        } else {
            self.ring[self.t % self.cap] = snapshot;
        }
        self.t += 1;
        let mut out = Vec::new();
        for &k in &self.ks {
            if self.t > k {
                let cur = &self.ring[(self.t - 1) % self.cap];
                let old = &self.ring[(self.t - 1 - k) % self.cap];
                out.push((k, sparsity_between(old, cur)));
            }
        }
        out
    }

    pub fn steps(&self) -> usize {
        self.t
    }
}

/// Fraction of bitwise-equal positions between two BF16 views. Counts
/// mismatches with the word-skipping scan from [`crate::sparse`] (equal
/// data — the common case at >99% sparsity — is dismissed 8 elements
/// per compare).
pub fn sparsity_between(a: &[u16], b: &[u16]) -> f64 {
    assert_eq!(a.len(), b.len());
    let differ = crate::sparse::count_diff_bf16(a, b);
    (a.len() - differ) as f64 / a.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k_step_windows() {
        let mut m = SparsityMeter::new(vec![1, 2]);
        let n = 1000;
        let mut w = vec![1.0f32; n];
        assert!(m.record(&w).is_empty()); // t=1: no history
        // change 10% of weights per step (by >1 cell)
        for step in 0..5 {
            for i in (step * 100)..(step * 100 + 100) {
                w[i] *= 1.5;
            }
            let out = m.record(&w);
            let s1 = out.iter().find(|(k, _)| *k == 1).map(|(_, s)| *s).unwrap();
            assert!((s1 - 0.9).abs() < 1e-9, "s1={}", s1);
            if step >= 1 {
                let s2 = out.iter().find(|(k, _)| *k == 2).map(|(_, s)| *s).unwrap();
                assert!((s2 - 0.8).abs() < 1e-9, "s2={}", s2);
            }
        }
    }

    #[test]
    fn identical_views_are_fully_sparse() {
        let mut m = SparsityMeter::new(vec![1]);
        let w = vec![0.5f32; 100];
        m.record(&w);
        let out = m.record(&w);
        assert_eq!(out, vec![(1, 1.0)]);
    }

    #[test]
    fn sub_cell_drift_is_invisible() {
        // FP32 master drifts by < half a cell → BF16 view unchanged.
        let mut m = SparsityMeter::new(vec![1]);
        let mut w: Vec<f32> = (0..100).map(|i| 0.5 + i as f32 * 1e-4).collect();
        m.record(&w);
        for x in w.iter_mut() {
            *x += 1e-5; // cell radius at 0.5 is ~2e-3
        }
        let out = m.record(&w);
        assert_eq!(out[0].1, 1.0);
    }
}
