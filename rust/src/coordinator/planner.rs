//! Fan-out planning for relay distribution trees (ROADMAP: "deeper
//! (3+ level) trees with automatic fan-out planning from measured leaf
//! counts").
//!
//! Two layers, deliberately separated so each is testable alone:
//!
//! * [`FanoutShape`] — the *pure* balanced k-ary shape: given a
//!   measured leaf count and a per-hop fan-out cap, how many interior
//!   relays sit at each level, and which last-level relay parents each
//!   leaf. Minimal depth by construction ([`plan_shape`]), optionally
//!   deepened for experiments ([`plan_shape_with_depth`]). Property:
//!   every leaf reached exactly once, cap respected at every hop,
//!   depth minimal — checked by the `util::prop` test below.
//! * [`TopologyPlan`] — the shape *bound* to actual peer ids by
//!   [`bind`]: each relay slot gets a joined relay peer (join order,
//!   so survivors keep their slots across replans where possible),
//!   extra relays become standbys, and an under-provisioned cluster
//!   degrades gracefully (fewer levels, then cap overflow, then
//!   leaves directly on the root) instead of failing.
//!
//! The control plane ([`crate::net::control`]) recomputes a bound plan
//! per epoch (join, death) and pushes it as ASSIGN directives.

/// The balanced k-ary tree shape for one leaf population.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FanoutShape {
    /// Per-hop fan-out cap the shape was planned for (≥ 2).
    pub fanout_cap: usize,
    /// Leaves the shape was planned for (`leaf_parents.len()` when
    /// interior levels exist; kept separately so flat shapes — leaves
    /// straight on the root — still know their fan-out).
    pub leaf_count: usize,
    /// Interior relays per level; `relay_levels[0]` sits directly
    /// under the root, the last level parents the leaves. Empty =
    /// leaves attach straight to the root.
    pub relay_levels: Vec<usize>,
    /// Per leaf: index of its parent within the LAST relay level
    /// (unused when `relay_levels` is empty).
    pub leaf_parents: Vec<usize>,
}

impl FanoutShape {
    /// Hops from the root relay to a leaf (1 = leaves on the root).
    pub fn depth(&self) -> usize {
        self.relay_levels.len() + 1
    }

    /// Total interior relay slots the shape needs.
    pub fn relays_required(&self) -> usize {
        self.relay_levels.iter().sum()
    }

    /// Parent of relay `idx` at `level` (0-based): `None` = the root,
    /// `Some(i)` = relay `i` one level up. Round-robin, so sibling
    /// counts differ by at most one.
    pub fn relay_parent(&self, level: usize, idx: usize) -> Option<usize> {
        if level == 0 {
            None
        } else {
            Some(idx % self.relay_levels[level - 1])
        }
    }

    /// Children of relay `idx` at `level`: `(relay children at level+1,
    /// leaf children)` — exactly one of the two is non-empty in a
    /// well-formed shape.
    fn child_count(&self, level: usize, idx: usize) -> usize {
        if level + 1 < self.relay_levels.len() {
            (0..self.relay_levels[level + 1])
                .filter(|&i| i % self.relay_levels[level] == idx)
                .count()
        } else {
            self.leaf_parents.iter().filter(|&&p| p == idx).count()
        }
    }

    /// Largest child count over the root and every relay slot.
    pub fn max_fanout(&self) -> usize {
        if self.relay_levels.is_empty() {
            // flat: every leaf hangs on the root
            return self.leaf_count;
        }
        let mut max = self.relay_levels[0]; // root's children
        for (level, &count) in self.relay_levels.iter().enumerate() {
            for idx in 0..count {
                max = max.max(self.child_count(level, idx));
            }
        }
        max
    }
}

/// Minimal-depth balanced shape for `leaf_count` leaves under a
/// per-hop `fanout_cap` (clamped to ≥ 2).
pub fn plan_shape(leaf_count: usize, fanout_cap: usize) -> FanoutShape {
    plan_shape_with_depth(leaf_count, fanout_cap, 0)
}

/// Like [`plan_shape`], but with at least `min_relay_levels` interior
/// levels (failover experiments force 3+ hop trees this way even for
/// small leaf counts). Depth stays minimal whenever
/// `min_relay_levels` does not force otherwise.
pub fn plan_shape_with_depth(
    leaf_count: usize,
    fanout_cap: usize,
    min_relay_levels: usize,
) -> FanoutShape {
    let cap = fanout_cap.max(2);
    let mut relay_levels: Vec<usize> = Vec::new();
    if leaf_count > cap || (leaf_count > 0 && min_relay_levels > 0) {
        // last level: enough relays that no relay parents > cap leaves
        relay_levels.push(leaf_count.div_ceil(cap));
        // build upward until the top level fits under the root
        while relay_levels[0] > cap {
            let above = relay_levels[0].div_ceil(cap);
            relay_levels.insert(0, above);
        }
        // forced extra depth: single-relay chain levels on top (the
        // old top, ≤ cap relays, fits under one relay)
        while relay_levels.len() < min_relay_levels {
            relay_levels.insert(0, 1);
        }
    }
    let leaf_parents = match relay_levels.last() {
        Some(&last) => (0..leaf_count).map(|i| i % last).collect(),
        None => Vec::new(),
    };
    FanoutShape { fanout_cap: cap, leaf_count, relay_levels, leaf_parents }
}

/// What a bound peer connects upstream to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Upstream {
    /// The root relay (the publisher's own relay).
    Root,
    /// Another relay peer, by its control-plane peer id.
    Peer(u64),
    /// No upstream this epoch: detach and wait (spare relay).
    Standby,
}

/// One peer's place in a bound plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Assignment {
    pub peer: u64,
    pub upstream: Upstream,
    /// Hops from the publisher (1 = directly under the root relay).
    pub hop: u32,
}

/// A [`FanoutShape`] bound to joined peers for one epoch.
#[derive(Debug, Clone)]
pub struct TopologyPlan {
    pub epoch: u64,
    pub shape: FanoutShape,
    /// Relay assignments, level-major (level 0 first). Standby relays
    /// ride at the end with [`Upstream::Standby`].
    pub relays: Vec<Assignment>,
    /// One assignment per leaf, in the order given to [`bind`].
    pub leaves: Vec<Assignment>,
}

impl TopologyPlan {
    /// Hops from root to leaf under this plan.
    pub fn depth(&self) -> usize {
        self.shape.depth()
    }

    /// The assignment for `peer`, if it is part of the plan.
    pub fn assignment_of(&self, peer: u64) -> Option<Assignment> {
        self.relays
            .iter()
            .chain(self.leaves.iter())
            .find(|a| a.peer == peer)
            .copied()
    }
}

/// Order the live relays for binding so peers holding ACTIVE slots in
/// `prev` keep exactly those slots: a dead peer's slot is a *hole*
/// filled by a spare (a previous standby, or a new joiner) rather than
/// shifting every later slot down — this is what confines a replan's
/// rewiring to the dead peer's own subtree. Peers never seen before
/// (and unfillable holes, when the cluster truly shrank) append/close
/// in join order. With no previous plan this is join order unchanged.
pub fn stable_relay_order(prev: Option<&TopologyPlan>, live: &[u64]) -> Vec<u64> {
    // Hash-set membership instead of Vec::contains: this runs on every
    // replan, and at simulated scale (100k+ peers churning) the old
    // O(slots × live) scans dominated the control plane. Output is
    // identical — the sets only answer membership, all ordering still
    // comes from `prev` slot order and `live` join order.
    use std::collections::HashSet;
    let Some(prev) = prev else { return live.to_vec() };
    let prev_active: Vec<u64> = prev
        .relays
        .iter()
        .filter(|a| a.upstream != Upstream::Standby)
        .map(|a| a.peer)
        .collect();
    let active_set: HashSet<u64> = prev_active.iter().copied().collect();
    let live_set: HashSet<u64> = live.iter().copied().collect();
    let mut spares: std::collections::VecDeque<u64> =
        live.iter().copied().filter(|id| !active_set.contains(id)).collect();
    let mut out = Vec::with_capacity(live.len());
    for id in &prev_active {
        if live_set.contains(id) {
            out.push(*id);
        } else if let Some(s) = spares.pop_front() {
            out.push(s);
        }
        // dead slot and no spare left: the hole closes and later
        // slots shift — unavoidable when the cluster truly shrank
    }
    out.extend(spares);
    out
}

/// Bind a shape to the live peers. `relay_peers` and `leaf_peers` are
/// the control plane's live sets — relays pre-ordered by
/// [`stable_relay_order`] so survivors keep their slots across replans
/// and only orphaned subtrees rewire; leaves in join order.
///
/// Degradation when relays are scarce: first the forced extra depth is
/// given up, then levels are collapsed to a single tier of however
/// many relays exist (each carrying more than `fanout_cap` leaves if
/// it must), and with no relays at all every leaf attaches straight to
/// the root. The plan never fails — a degraded tree that moves frames
/// beats an optimal tree that doesn't exist.
pub fn bind(
    epoch: u64,
    relay_peers: &[u64],
    leaf_peers: &[u64],
    fanout_cap: usize,
    min_relay_levels: usize,
) -> TopologyPlan {
    let mut shape = plan_shape_with_depth(leaf_peers.len(), fanout_cap, min_relay_levels);
    if shape.relays_required() > relay_peers.len() {
        shape = plan_shape(leaf_peers.len(), fanout_cap);
    }
    if shape.relays_required() > relay_peers.len() {
        // under-provisioned: one tier of whatever relays exist
        let last = relay_peers.len();
        shape = FanoutShape {
            fanout_cap: fanout_cap.max(2),
            leaf_count: leaf_peers.len(),
            relay_levels: if last > 0 { vec![last] } else { Vec::new() },
            leaf_parents: if last > 0 {
                (0..leaf_peers.len()).map(|i| i % last).collect()
            } else {
                Vec::new()
            },
        };
    }

    // bind relay slots level-major in join order
    let mut relays = Vec::with_capacity(relay_peers.len());
    let mut level_base = Vec::with_capacity(shape.relay_levels.len()); // slot index of each level's first relay
    let mut next = 0usize;
    for (level, &count) in shape.relay_levels.iter().enumerate() {
        level_base.push(next);
        for idx in 0..count {
            let upstream = match shape.relay_parent(level, idx) {
                None => Upstream::Root,
                Some(p) => Upstream::Peer(relay_peers[level_base[level - 1] + p]),
            };
            relays.push(Assignment {
                peer: relay_peers[next],
                upstream,
                hop: level as u32 + 1,
            });
            next += 1;
        }
    }
    for &spare in &relay_peers[next..] {
        relays.push(Assignment { peer: spare, upstream: Upstream::Standby, hop: 0 });
    }

    let leaf_level_base = level_base.last().copied().unwrap_or(0);
    let leaves = leaf_peers
        .iter()
        .enumerate()
        .map(|(i, &peer)| match shape.leaf_parents.get(i) {
            Some(&p) => Assignment {
                peer,
                upstream: Upstream::Peer(relay_peers[leaf_level_base + p]),
                hop: shape.depth() as u32,
            },
            None => Assignment { peer, upstream: Upstream::Root, hop: 1 },
        })
        .collect();

    TopologyPlan { epoch, shape, relays, leaves }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Smallest d ≥ 1 with cap^d ≥ leaves (the information-theoretic
    /// depth floor for a cap-ary tree).
    fn minimal_depth(leaves: usize, cap: usize) -> usize {
        let mut d = 1usize;
        let mut reach = cap;
        while reach < leaves {
            reach = reach.saturating_mul(cap);
            d += 1;
        }
        d
    }

    #[test]
    fn shape_property_coverage_cap_and_minimal_depth() {
        // satellite: for ANY leaf count 1..=256 and cap 2..=8 the plan
        // reaches every leaf exactly once, respects the cap at every
        // hop, and uses minimal depth
        crate::util::prop::check("fanout shape is covering, capped, minimal", 64, |g| {
            let leaves = 1 + g.rng.below(256) as usize;
            let cap = 2 + g.rng.below(7) as usize;
            let s = plan_shape(leaves, cap);
            assert_eq!(s.fanout_cap, cap);
            assert_eq!(s.leaf_count, leaves);
            // every leaf exactly once, parents in range
            if s.relay_levels.is_empty() {
                assert!(leaves <= cap, "flat shape must fit under the root");
                assert!(s.leaf_parents.is_empty());
                assert_eq!(s.max_fanout(), leaves, "flat fan-out is the root's");
            } else {
                assert_eq!(s.leaf_parents.len(), leaves);
                let last = *s.relay_levels.last().unwrap();
                assert!(s.leaf_parents.iter().all(|&p| p < last));
            }
            // cap respected at every hop (root, every relay)
            assert!(
                s.max_fanout() <= cap,
                "fanout {} exceeds cap {} (leaves={}, levels={:?})",
                s.max_fanout(),
                cap,
                leaves,
                s.relay_levels
            );
            // minimal depth
            assert_eq!(
                s.depth(),
                minimal_depth(leaves, cap),
                "depth not minimal for leaves={} cap={}",
                leaves,
                cap
            );
        });
    }

    #[test]
    fn forced_depth_pads_with_chain_levels() {
        let s = plan_shape_with_depth(4, 2, 2);
        assert_eq!(s.relay_levels, vec![1, 2]);
        assert_eq!(s.depth(), 3);
        assert!(s.max_fanout() <= 2);
        // forcing depth on an already-deep shape changes nothing
        let s = plan_shape_with_depth(100, 2, 2);
        assert_eq!(s, plan_shape(100, 2));
    }

    #[test]
    fn bind_assigns_slots_spares_and_leaf_parents() {
        // 4 leaves, cap 2, forced 2 interior levels → shape [1, 2];
        // 4 relays joined → 3 bound + 1 standby
        let plan = bind(5, &[10, 11, 12, 13], &[20, 21, 22, 23], 2, 2);
        assert_eq!(plan.epoch, 5);
        assert_eq!(plan.depth(), 3);
        assert_eq!(plan.relays.len(), 4);
        assert_eq!(
            plan.relays[0],
            Assignment { peer: 10, upstream: Upstream::Root, hop: 1 }
        );
        assert_eq!(
            plan.relays[1],
            Assignment { peer: 11, upstream: Upstream::Peer(10), hop: 2 }
        );
        assert_eq!(
            plan.relays[2],
            Assignment { peer: 12, upstream: Upstream::Peer(10), hop: 2 }
        );
        assert_eq!(
            plan.relays[3],
            Assignment { peer: 13, upstream: Upstream::Standby, hop: 0 }
        );
        // leaves round-robin across the last level (peers 11, 12)
        let parents: Vec<Upstream> = plan.leaves.iter().map(|a| a.upstream).collect();
        assert_eq!(
            parents,
            vec![
                Upstream::Peer(11),
                Upstream::Peer(12),
                Upstream::Peer(11),
                Upstream::Peer(12)
            ]
        );
        assert!(plan.leaves.iter().all(|a| a.hop == 3));
        assert_eq!(plan.assignment_of(13).unwrap().upstream, Upstream::Standby);
        assert_eq!(plan.assignment_of(99), None);
    }

    #[test]
    fn bind_degrades_when_under_provisioned() {
        // 4 leaves, cap 2 wants 2 last-level relays; only 1 joined →
        // that relay carries all 4 (cap overflow beats no tree)
        let plan = bind(1, &[7], &[1, 2, 3, 4], 2, 0);
        assert_eq!(plan.shape.relay_levels, vec![1]);
        assert!(plan.leaves.iter().all(|a| a.upstream == Upstream::Peer(7)));
        // no relays at all → leaves on the root
        let plan = bind(2, &[], &[1, 2, 3], 2, 1);
        assert!(plan.relays.is_empty());
        assert!(plan
            .leaves
            .iter()
            .all(|a| a.upstream == Upstream::Root && a.hop == 1));
        // forced depth is the first thing surrendered
        let plan = bind(3, &[7, 8], &[1, 2, 3, 4], 2, 2);
        assert_eq!(plan.shape.relay_levels, vec![2], "depth padding dropped first");
        assert!(plan.relays.iter().all(|a| a.upstream == Upstream::Root));
    }

    #[test]
    fn survivors_keep_slots_across_replans() {
        // shape [2]: 10 and 11 active, 12 standby; leaves alternate
        // parents 10, 11, 10, 11
        let before = bind(1, &[10, 11, 12], &[20, 21, 22, 23], 2, 0);
        assert_eq!(before.assignment_of(21).unwrap().upstream, Upstream::Peer(11));
        // kill the SLOT-0 peer (10): the spare must fill the hole, so
        // slot 1's occupant (11) — and therefore its leaves — stay put
        let order = stable_relay_order(Some(&before), &[11, 12]);
        assert_eq!(order, vec![12, 11], "spare fills the hole; slot 1 unmoved");
        let after = bind(2, &order, &[20, 21, 22, 23], 2, 0);
        assert_eq!(
            after.assignment_of(21).unwrap().upstream,
            Upstream::Peer(11),
            "a non-orphan leaf must keep its parent"
        );
        assert_eq!(after.assignment_of(20).unwrap().upstream, Upstream::Peer(12));
        // no previous plan → join order passes through
        assert_eq!(stable_relay_order(None, &[5, 6]), vec![5, 6]);
        // hole with no spare left: later slots shift (truly shrank)
        assert_eq!(stable_relay_order(Some(&before), &[11]), vec![11]);
        // a new joiner appends after the surviving slots
        assert_eq!(
            stable_relay_order(Some(&before), &[10, 11, 12, 13]),
            vec![10, 11, 12, 13]
        );
    }
}
