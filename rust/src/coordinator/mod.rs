//! Training coordinator: the leader loop that composes the runtime
//! (L2/L1 graphs), GRPO, AdamW, the sparsity meter, and the three
//! trainer-to-trainer methods (DDP / DiLoCo / PULSELoCo) under the
//! paper's shared-rollout-checkpoint protocol (§J.2): rollout workers
//! serve the latest *global* checkpoint and are refreshed only at
//! outer-round boundaries.

pub mod metrics;
pub mod planner;
pub mod sparsity;

use crate::optim::{AdamConfig, AdamW};
use crate::pulse::loco::{OuterLoop, OuterMethod, RoundStats};
use crate::rl::grpo::{self, GrpoConfig};
use crate::rl::tasks::{CodeTask, MathTask};
use crate::rl::Task;
use crate::runtime::ModelRuntime;
use crate::util::rng::Rng;
use anyhow::Result;
use sparsity::SparsityMeter;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// One trainer (the §3 sparsity-characterization setting).
    Single,
    /// Per-step dense gradient all-reduce across R workers.
    Ddp,
    /// Dense FP32 pseudo-gradient sync every H steps.
    DiLoCo,
    /// BF16-gated sparse pseudo-gradient sync with error feedback.
    PulseLoCo,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Single => "single",
            Method::Ddp => "ddp",
            Method::DiLoCo => "diloco",
            Method::PulseLoCo => "pulseloco",
        }
    }

    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "single" => Method::Single,
            "ddp" => Method::Ddp,
            "diloco" => Method::DiLoCo,
            "pulseloco" | "pulse" => Method::PulseLoCo,
            other => anyhow::bail!("unknown method '{}'", other),
        })
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    Math,
    Code,
}

pub fn make_task(kind: TaskKind) -> Box<dyn Task> {
    match kind {
        TaskKind::Math => Box::new(MathTask::default()),
        TaskKind::Code => Box::new(CodeTask::default()),
    }
}

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub method: Method,
    /// R trainers (paper uses 4).
    pub workers: usize,
    /// H local steps per outer round (DiLoCo/PULSELoCo).
    pub local_steps: usize,
    /// Total optimizer steps per worker.
    pub steps: usize,
    /// Rollout refresh interval S for Single (paper Fig. 4); multi-
    /// trainer methods refresh at round boundaries per §J.2.
    pub rollout_interval: usize,
    pub adam: AdamConfig,
    pub grpo: GrpoConfig,
    pub seed: u64,
    /// Evaluate pass@1 every this many global steps (0 = only at end).
    pub eval_every: usize,
    pub n_eval: usize,
    pub sparsity_ks: Vec<usize>,
    pub task: TaskKind,
    /// Capture a BF16 checkpoint snapshot every N steps (0 = never) —
    /// feeds the codec/compression tables.
    pub capture_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            method: Method::Single,
            workers: 1,
            local_steps: 8,
            steps: 50,
            rollout_interval: 1,
            adam: AdamConfig::default(),
            grpo: GrpoConfig::default(),
            seed: 0,
            eval_every: 0,
            n_eval: 64,
            sparsity_ks: vec![1, 8, 16, 32],
            task: TaskKind::Math,
            capture_every: 0,
        }
    }
}

/// Per-optimizer-step log record.
#[derive(Debug, Clone, Default)]
pub struct StepLog {
    pub step: u64,
    pub loss: f64,
    pub mean_reward: f64,
    pub correct_rate: f64,
    pub grad_density: f64,
    pub lr: f64,
    pub rho_mean: f64,
    pub rho_max: f64,
    /// (k, S_k) sparsity measurements available at this step.
    pub sparsity: Vec<(usize, f64)>,
    pub pass_at_1: Option<f64>,
}

/// Per-outer-round log (multi-trainer methods).
#[derive(Debug, Clone, Default)]
pub struct RoundLog {
    pub round: u64,
    pub global_step: u64,
    pub mean_loss: f64,
    pub mean_reward: f64,
    pub pass_at_1: Option<f64>,
    /// Per-worker communication stats for this round.
    pub comm: Vec<RoundStats>,
    /// BF16 checkpoint-patch sparsity between consecutive global
    /// checkpoints (the paired-PULSESync measurement of Fig. 10 left).
    pub ckpt_sparsity: f64,
}

#[derive(Debug, Clone, Default)]
pub struct TrainResult {
    pub steps: Vec<StepLog>,
    pub rounds: Vec<RoundLog>,
    pub final_pass_at_1: f64,
    /// Captured BF16 checkpoints (step, view) for codec tables.
    pub captures: Vec<(u64, Vec<u16>)>,
}

/// Run training per `cfg` against a loaded runtime. Single-threaded and
/// deterministic given (cfg.seed, runtime artifacts).
pub fn train(rt: &ModelRuntime, cfg: &TrainConfig) -> Result<TrainResult> {
    match cfg.method {
        Method::Single => train_single(rt, cfg),
        Method::Ddp => train_ddp(rt, cfg),
        Method::DiLoCo | Method::PulseLoCo => train_local_update(rt, cfg),
    }
}

fn bf16_view_f32(master: &[f32]) -> Vec<f32> {
    master.iter().map(|&x| crate::bf16::bf16_round(x)).collect()
}

fn train_single(rt: &ModelRuntime, cfg: &TrainConfig) -> Result<TrainResult> {
    let task = make_task(cfg.task);
    let mut rng = Rng::new(cfg.seed);
    let mut master = init_master(rt, cfg.seed)?;
    let mut opt = AdamW::new(master.len(), cfg.adam);
    let mut meter = SparsityMeter::new(cfg.sparsity_ks.clone());
    let mut result = TrainResult::default();
    // record the initial view so k=1 is available from step 1
    meter.record(&master);
    let mut rollout_policy = bf16_view_f32(&master);
    for step in 1..=cfg.steps as u64 {
        // refresh rollout policy every S steps (S=1 → fully on-policy)
        if (step - 1) % cfg.rollout_interval.max(1) as u64 == 0 {
            rollout_policy = bf16_view_f32(&master);
        }
        let batch = grpo::generate_batch(rt, &rollout_policy, task.as_ref(), cfg.grpo, &mut rng)?;
        let out = rt.grad(
            &master,
            &batch.tokens,
            &batch.advantages,
            &batch.old_logprobs,
            &batch.mask,
        )?;
        let st = opt.step(&mut master, &out.grads);
        let sparsity = meter.record(&master);
        let pass_at_1 = if cfg.eval_every > 0 && step % cfg.eval_every as u64 == 0 {
            Some(grpo::pass_at_1(rt, &bf16_view_f32(&master), task.as_ref(), cfg.n_eval, &mut rng)?)
        } else {
            None
        };
        if cfg.capture_every > 0 && step % cfg.capture_every as u64 == 0 {
            let mut view = Vec::new();
            crate::bf16::cast_slice_par(&master, &mut view);
            result.captures.push((step, view));
        }
        result.steps.push(StepLog {
            step,
            loss: out.loss as f64,
            mean_reward: batch.mean_reward,
            correct_rate: batch.correct_rate,
            grad_density: out.grad_density as f64,
            lr: st.lr as f64,
            rho_mean: st.rho_mean as f64,
            rho_max: st.rho_max as f64,
            sparsity,
            pass_at_1,
        });
    }
    result.final_pass_at_1 =
        grpo::pass_at_1(rt, &bf16_view_f32(&master), task.as_ref(), cfg.n_eval, &mut rng)?;
    Ok(result)
}

fn train_ddp(rt: &ModelRuntime, cfg: &TrainConfig) -> Result<TrainResult> {
    let task = make_task(cfg.task);
    let mut rng = Rng::new(cfg.seed);
    let mut shard_rngs: Vec<Rng> = (0..cfg.workers).map(|w| rng.fork(w as u64)).collect();
    let mut master = init_master(rt, cfg.seed)?;
    let mut opt = AdamW::new(master.len(), cfg.adam);
    let mut result = TrainResult::default();
    let rounds = cfg.steps / cfg.local_steps.max(1);
    let mut global_step = 0u64;
    for round in 1..=rounds as u64 {
        let mut mean_loss = 0.0;
        let mut mean_reward = 0.0;
        for _ in 0..cfg.local_steps {
            global_step += 1;
            let policy = bf16_view_f32(&master); // DDP is on-policy
            let mut grads: Vec<Vec<f32>> = Vec::with_capacity(cfg.workers);
            for w in 0..cfg.workers {
                let batch =
                    grpo::generate_batch(rt, &policy, task.as_ref(), cfg.grpo, &mut shard_rngs[w])?;
                let out = rt.grad(
                    &master,
                    &batch.tokens,
                    &batch.advantages,
                    &batch.old_logprobs,
                    &batch.mask,
                )?;
                mean_loss += out.loss as f64;
                mean_reward += batch.mean_reward;
                grads.push(out.grads);
            }
            crate::baselines::allreduce_mean(&mut grads);
            opt.step(&mut master, &grads[0]);
        }
        let denom = (cfg.local_steps * cfg.workers) as f64;
        let pass_at_1 = if should_eval(cfg, round, rounds as u64) {
            Some(grpo::pass_at_1(rt, &bf16_view_f32(&master), task.as_ref(), cfg.n_eval, &mut rng)?)
        } else {
            None
        };
        // communication: H dense FP32 grads per worker per round
        let comm = (0..cfg.workers)
            .map(|_| RoundStats {
                round,
                comm_sparsity: 0.0,
                raw_payload_bytes: crate::baselines::ddp_bytes_per_round(
                    master.len() as u64,
                    cfg.local_steps as u64,
                ),
                encoded_payload_bytes: crate::baselines::ddp_bytes_per_round(
                    master.len() as u64,
                    cfg.local_steps as u64,
                ),
                shuffled_zstd3_bytes: crate::baselines::ddp_bytes_per_round(
                    master.len() as u64,
                    cfg.local_steps as u64,
                ),
                dense_bytes: crate::baselines::ddp_bytes_per_round(
                    master.len() as u64,
                    cfg.local_steps as u64,
                ),
                residual_l1: 0.0,
            })
            .collect();
        result.rounds.push(RoundLog {
            round,
            global_step,
            mean_loss: mean_loss / denom,
            mean_reward: mean_reward / denom,
            pass_at_1,
            comm,
            ckpt_sparsity: 0.0,
        });
    }
    result.final_pass_at_1 =
        grpo::pass_at_1(rt, &bf16_view_f32(&master), task.as_ref(), cfg.n_eval, &mut rng)?;
    Ok(result)
}

fn train_local_update(rt: &ModelRuntime, cfg: &TrainConfig) -> Result<TrainResult> {
    let task = make_task(cfg.task);
    let mut rng = Rng::new(cfg.seed);
    let mut shard_rngs: Vec<Rng> = (0..cfg.workers).map(|w| rng.fork(w as u64)).collect();
    let theta0 = init_master(rt, cfg.seed)?;
    let method = if cfg.method == Method::DiLoCo {
        OuterMethod::DiLoCo
    } else {
        OuterMethod::PulseLoCo
    };
    let mut outer = OuterLoop::new(method, theta0, cfg.workers);
    // persistent inner Adam state per worker (standard DiLoCo practice)
    let mut inner: Vec<AdamW> =
        (0..cfg.workers).map(|_| AdamW::new(outer.theta.len(), cfg.adam)).collect();
    let mut result = TrainResult::default();
    let rounds = cfg.steps / cfg.local_steps.max(1);
    let mut global_step = 0u64;
    let mut prev_ckpt: Vec<u16> = Vec::new();
    crate::bf16::cast_slice_par(&outer.theta, &mut prev_ckpt);
    for round in 1..=rounds as u64 {
        // rollout workers serve the shared global checkpoint (§J.2)
        let rollout_policy = bf16_view_f32(&outer.theta);
        let mut mean_loss = 0.0;
        let mut mean_reward = 0.0;
        let mut locals: Vec<Vec<f32>> = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let mut local = outer.theta.clone();
            for _ in 0..cfg.local_steps {
                global_step += 1;
                let batch = grpo::generate_batch(
                    rt,
                    &rollout_policy,
                    task.as_ref(),
                    cfg.grpo,
                    &mut shard_rngs[w],
                )?;
                let out = rt.grad(
                    &local,
                    &batch.tokens,
                    &batch.advantages,
                    &batch.old_logprobs,
                    &batch.mask,
                )?;
                inner[w].step(&mut local, &out.grads);
                mean_loss += out.loss as f64;
                mean_reward += batch.mean_reward;
            }
            locals.push(local);
        }
        let comm = outer.round(&locals)?;
        // paired PULSESync measurement: patch sparsity between global
        // checkpoints (each spans H local steps + one outer update)
        let mut ckpt = Vec::new();
        crate::bf16::cast_slice_par(&outer.theta, &mut ckpt);
        let ckpt_sparsity = sparsity::sparsity_between(&prev_ckpt, &ckpt);
        prev_ckpt = ckpt;
        let denom = (cfg.local_steps * cfg.workers) as f64;
        let pass_at_1 = if should_eval(cfg, round, rounds as u64) {
            Some(grpo::pass_at_1(
                rt,
                &bf16_view_f32(&outer.theta),
                task.as_ref(),
                cfg.n_eval,
                &mut rng,
            )?)
        } else {
            None
        };
        result.rounds.push(RoundLog {
            round,
            global_step,
            mean_loss: mean_loss / denom,
            mean_reward: mean_reward / denom,
            pass_at_1,
            comm,
            ckpt_sparsity,
        });
    }
    result.final_pass_at_1 = grpo::pass_at_1(
        rt,
        &bf16_view_f32(&outer.theta),
        task.as_ref(),
        cfg.n_eval,
        &mut rng,
    )?;
    Ok(result)
}

fn should_eval(cfg: &TrainConfig, round: u64, total_rounds: u64) -> bool {
    if cfg.eval_every == 0 {
        return round == total_rounds;
    }
    let steps_per_round = cfg.local_steps.max(1) as u64;
    (round * steps_per_round) % cfg.eval_every as u64 == 0 || round == total_rounds
}

/// Initialize the master weights: use the shipped init.bin when the
/// size provides one (so runs are comparable with the python oracle),
/// otherwise a magnitude-calibrated random init.
pub fn init_master(rt: &ModelRuntime, seed: u64) -> Result<Vec<f32>> {
    if rt.manifest.init.is_some() {
        let mut flat = rt.load_init(&crate::runtime::artifacts_dir())?;
        if seed != 0 {
            // decorrelate seeds: tiny sub-cell jitter (invisible to BF16
            // at init, but changes rollout sampling via logits noise
            // after the first few updates) plus reshuffled sign pattern
            // would alter the model; instead we perturb at half-cell
            // scale so runs differ while magnitudes stay calibrated.
            let mut rng = Rng::new(seed);
            for x in flat.iter_mut() {
                let cell = crate::bf16::bf16_ulp(*x);
                *x += (rng.f32() - 0.5) * cell;
            }
        }
        Ok(flat)
    } else {
        // large/xl sizes ship no init.bin (it would be hundreds of MB);
        // generate the same magnitude-calibrated scheme as
        // model.init_params: fan-in-scaled normals, γ=1, b=0.
        let mut rng = Rng::new(0xC0DE ^ seed);
        let mut flat = vec![0.0f32; rt.manifest.n_params];
        for t in &rt.manifest.layout {
            let seg = &mut flat[t.offset..t.offset + t.len()];
            if t.name.ends_with("_g") {
                seg.fill(1.0);
            } else if t.name.ends_with("_b") || t.name.ends_with("b1") || t.name.ends_with("b2")
            {
                seg.fill(0.0);
            } else if t.name == "embed" || t.name == "pos" {
                rng.fill_normal_f32(seg, 0.02);
            } else {
                let std = 1.0 / (t.rows as f32).sqrt();
                rng.fill_normal_f32(seg, std);
            }
        }
        Ok(flat)
    }
}
