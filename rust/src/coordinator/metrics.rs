//! CSV metrics emission for the paper harness (`results/*.csv`) — every
//! figure/table is regenerated from these files — plus the per-shard
//! fan-out meter ([`ShardFanoutMeter`]) that tracks bytes/latency per
//! shard of the sharded publish path (`pulse::sync`), the
//! per-transport meter ([`TransportMeter`]) that accumulates sync-plane
//! traffic per `net::transport` backend, and the latency-histogram
//! exporter ([`ObsExport`]) that lands the process-global observability
//! hub's tail quantiles in `results/obs_hist.csv`.

use crate::net::transport::TransportCounters;
use crate::pulse::sync::{SyncPath, SyncStats};
use anyhow::Result;
use std::io::Write;
use std::path::{Path, PathBuf};

pub struct CsvWriter {
    file: std::fs::File,
    pub path: PathBuf,
    n_cols: usize,
}

impl CsvWriter {
    /// Create (truncate) `path` with the given header columns.
    pub fn create(path: &Path, columns: &[&str]) -> Result<CsvWriter> {
        if let Some(p) = path.parent() {
            std::fs::create_dir_all(p)?;
        }
        let mut file = std::fs::File::create(path)?;
        writeln!(file, "{}", columns.join(","))?;
        Ok(CsvWriter { file, path: path.to_path_buf(), n_cols: columns.len() })
    }

    pub fn row(&mut self, values: &[String]) -> Result<()> {
        anyhow::ensure!(values.len() == self.n_cols, "row width mismatch");
        writeln!(self.file, "{}", values.join(","))?;
        Ok(())
    }

    pub fn rowf(&mut self, values: &[f64]) -> Result<()> {
        self.row(&values.iter().map(|v| format!("{v}")).collect::<Vec<_>>())
    }
}

/// Accumulates per-shard publish accounting (bytes + encode seconds per
/// shard index) across steps, from `PublishStats::shard_bytes` /
/// `shard_encode_secs`. Feeds `results/shard_fanout.csv` and gives a
/// quick balance check: a skewed `byte_imbalance()` means the shard
/// ranges are not splitting the update stream evenly.
#[derive(Debug, Default)]
pub struct ShardFanoutMeter {
    steps: u64,
    bytes: Vec<u64>,
    secs: Vec<f64>,
}

impl ShardFanoutMeter {
    pub fn new() -> ShardFanoutMeter {
        ShardFanoutMeter::default()
    }

    /// Record one published step's per-shard bytes and encode seconds.
    pub fn record(&mut self, shard_bytes: &[u64], shard_secs: &[f64]) {
        if self.bytes.len() < shard_bytes.len() {
            self.bytes.resize(shard_bytes.len(), 0);
        }
        if self.secs.len() < shard_secs.len() {
            self.secs.resize(shard_secs.len(), 0.0);
        }
        for (i, b) in shard_bytes.iter().enumerate() {
            self.bytes[i] += b;
        }
        for (i, s) in shard_secs.iter().enumerate() {
            self.secs[i] += s;
        }
        self.steps += 1;
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    pub fn shard_count(&self) -> usize {
        self.bytes.len()
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes.iter().sum()
    }

    /// Max shard bytes over mean shard bytes (1.0 = perfectly
    /// balanced; 0.0 when nothing was recorded).
    pub fn byte_imbalance(&self) -> f64 {
        let total = self.total_bytes();
        if total == 0 || self.bytes.is_empty() {
            return 0.0;
        }
        let mean = total as f64 / self.bytes.len() as f64;
        let max = self.bytes.iter().copied().max().unwrap_or(0) as f64;
        max / mean
    }

    /// One CSV row per shard: totals plus per-step means.
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        let mut w = CsvWriter::create(
            path,
            &["shard", "steps", "total_bytes", "total_encode_secs", "mean_bytes_per_step"],
        )?;
        for (i, (&b, &s)) in self.bytes.iter().zip(&self.secs).enumerate() {
            w.row(&[
                i.to_string(),
                self.steps.to_string(),
                b.to_string(),
                format!("{:.6}", s),
                format!("{:.1}", b as f64 / self.steps.max(1) as f64),
            ])?;
        }
        Ok(())
    }
}

/// Accumulates sync-plane traffic per transport backend: one row per
/// backend label, fed from [`TransportCounters`] snapshots plus the
/// consumer's `SyncStats` refetch/path tallies. Chained-relay
/// topologies label one row per hop ([`TransportMeter::set_hop`]), so
/// the `paper topology` table can show where in the tree each cost is
/// paid; control-plane runs carry `reparents`/`epoch` columns so
/// `results/topology.csv`-style tables can show failover cost. Feeds
/// `results/transport_plane.csv` / `results/topology.csv` /
/// `results/control_plane.csv` and the `paper transports` / `paper
/// topology` / `paper control` tables, so the per-backend cost of the
/// same PULSESync stream is directly comparable.
#[derive(Debug, Default)]
pub struct TransportMeter {
    rows: Vec<TransportRow>,
}

#[derive(Debug, Clone, Default)]
pub struct TransportRow {
    pub transport: String,
    /// Relay hops between this row's peer and the publisher (0 for
    /// non-relay backends and the root).
    pub hop: u32,
    pub publishes: u64,
    pub syncs: u64,
    pub counters: TransportCounters,
    pub shard_refetches: u64,
    pub slow_paths: u64,
    pub bytes_downloaded: u64,
    pub patches_applied: u64,
    pub anchors_restored: u64,
    /// Highest publisher generation any synchronize() on this backend
    /// anchored against (folded with max, not summed).
    pub generation: u64,
}

impl TransportMeter {
    pub fn new() -> TransportMeter {
        TransportMeter::default()
    }

    fn row_mut(&mut self, transport: &str) -> &mut TransportRow {
        if let Some(i) = self.rows.iter().position(|r| r.transport == transport) {
            return &mut self.rows[i];
        }
        self.rows.push(TransportRow { transport: transport.to_string(), ..Default::default() });
        self.rows.last_mut().unwrap()
    }

    /// Record one publish on `transport` (counter deltas are absorbed
    /// by [`TransportMeter::set_counters`] at the end of a run).
    pub fn record_publish(&mut self, transport: &str) {
        self.row_mut(transport).publishes += 1;
    }

    /// Record one synchronize() outcome on `transport`, folding the
    /// call's [`SyncStats`] into the backend's row.
    pub fn record_sync(&mut self, transport: &str, stats: &SyncStats) {
        let row = self.row_mut(transport);
        row.syncs += 1;
        row.shard_refetches += stats.shard_refetches as u64;
        if stats.path == SyncPath::Slow {
            row.slow_paths += 1;
        }
        row.bytes_downloaded += stats.bytes_downloaded;
        row.patches_applied += stats.patches_applied as u64;
        row.anchors_restored += stats.anchors_restored as u64;
        row.generation = row.generation.max(stats.generation);
    }

    /// Attach the final counter snapshot for `transport`.
    pub fn set_counters(&mut self, transport: &str, counters: TransportCounters) {
        self.row_mut(transport).counters = counters;
    }

    /// Record the row's distance from the publisher in relay hops
    /// (chained topologies; leave 0 for flat backends).
    pub fn set_hop(&mut self, transport: &str, hop: u32) {
        self.row_mut(transport).hop = hop;
    }

    pub fn rows(&self) -> &[TransportRow] {
        &self.rows
    }

    /// One CSV row per backend.
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        let mut w = CsvWriter::create(
            path,
            &[
                "transport",
                "hop",
                "publishes",
                "syncs",
                "inventory_scans",
                "frames_published",
                "bytes_published",
                "markers_published",
                "frames_fetched",
                "bytes_fetched",
                "nacks_sent",
                "nacks_unserviceable",
                "retries",
                "gave_up",
                "nack_suppressed",
                "faults_injected",
                "cache_hits",
                "cache_misses",
                "origin_fetches",
                "conditional_not_modified",
                "shard_refetches",
                "slow_paths",
                "bytes_downloaded",
                "patches_applied",
                "anchors_restored",
                "generation",
                "reparents",
                "epoch",
            ],
        )?;
        for r in &self.rows {
            w.row(&[
                r.transport.clone(),
                r.hop.to_string(),
                r.publishes.to_string(),
                r.syncs.to_string(),
                r.counters.inventory_scans.to_string(),
                r.counters.frames_published.to_string(),
                r.counters.bytes_published.to_string(),
                r.counters.markers_published.to_string(),
                r.counters.frames_fetched.to_string(),
                r.counters.bytes_fetched.to_string(),
                r.counters.nacks_sent.to_string(),
                r.counters.nacks_unserviceable.to_string(),
                r.counters.retries.to_string(),
                r.counters.gave_up.to_string(),
                r.counters.nack_suppressed.to_string(),
                r.counters.faults_injected.to_string(),
                r.counters.cache_hits.to_string(),
                r.counters.cache_misses.to_string(),
                r.counters.origin_fetches.to_string(),
                r.counters.conditional_not_modified.to_string(),
                r.shard_refetches.to_string(),
                r.slow_paths.to_string(),
                r.bytes_downloaded.to_string(),
                r.patches_applied.to_string(),
                r.anchors_restored.to_string(),
                r.generation.to_string(),
                r.counters.reparents.to_string(),
                r.counters.epoch.to_string(),
            ])?;
        }
        Ok(())
    }
}

/// Exports the process-global observability hub ([`crate::obs::Obs`])
/// to `results/obs_hist.csv`: one row per latency histogram with its
/// sample count, mean, and tail quantiles. The histogram list written
/// here mirrors [`crate::obs::Obs::hist_names`] — the
/// `counter-csv-drift` lint rule fails the tree when the two drift
/// apart, exactly like the `TransportCounters` ↔ [`TransportMeter`]
/// column pairing.
#[derive(Debug, Default)]
pub struct ObsExport;

impl ObsExport {
    pub fn new() -> ObsExport {
        ObsExport
    }

    /// One CSV row per registered histogram, read live from
    /// [`crate::obs::Obs::global`].
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        let mut w = CsvWriter::create(
            path,
            &["hist", "count", "mean_us", "p50_us", "p99_us", "p999_us", "max_us"],
        )?;
        let obs = crate::obs::Obs::global();
        for name in ["nack_repair_us", "catch_up_us", "store_rpc_us", "e2e_step_us"] {
            let h = obs
                .hist_named(name)
                .ok_or_else(|| anyhow::anyhow!("histogram `{name}` is not registered"))?;
            w.row(&[
                name.to_string(),
                h.count().to_string(),
                format!("{:.1}", h.mean_us()),
                h.p50_us().to_string(),
                h.p99_us().to_string(),
                h.p999_us().to_string(),
                h.max_us().to_string(),
            ])?;
        }
        Ok(())
    }
}

/// Results directory: `$PULSE_RESULTS` or `<repo>/results`.
pub fn results_dir() -> PathBuf {
    if let Ok(d) = std::env::var("PULSE_RESULTS") {
        return PathBuf::from(d);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("results")
}

/// Render an aligned text table (paper-style rows) to stdout.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {} ==", title);
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!("{}", fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_fanout_meter_accumulates() {
        let mut m = ShardFanoutMeter::new();
        assert_eq!(m.byte_imbalance(), 0.0);
        m.record(&[100, 100, 100, 100], &[0.1, 0.1, 0.1, 0.1]);
        m.record(&[300, 100, 100, 100], &[0.2, 0.1, 0.1, 0.1]);
        assert_eq!(m.steps(), 2);
        assert_eq!(m.shard_count(), 4);
        assert_eq!(m.total_bytes(), 1000);
        // shard 0 carried 400 of 1000 bytes over 4 shards → 1.6x mean
        assert!((m.byte_imbalance() - 1.6).abs() < 1e-9);
        let dir = std::env::temp_dir().join(format!("pulse_shardcsv_{}", std::process::id()));
        let p = dir.join("shard_fanout.csv");
        m.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 5, "header + one row per shard");
        assert!(text.lines().nth(1).unwrap().starts_with("0,2,400,"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn transport_meter_accumulates_per_backend() {
        let mut m = TransportMeter::new();
        m.record_publish("in-proc");
        m.record_publish("in-proc");
        m.record_sync(
            "in-proc",
            &SyncStats { shard_refetches: 1, path: SyncPath::Fast, ..Default::default() },
        );
        m.record_sync(
            "object-store",
            &SyncStats {
                path: SyncPath::Slow,
                bytes_downloaded: 2048,
                patches_applied: 3,
                anchors_restored: 1,
                generation: 2,
                ..Default::default()
            },
        );
        m.set_counters(
            "in-proc",
            TransportCounters { inventory_scans: 2, bytes_fetched: 512, ..Default::default() },
        );
        m.set_counters(
            "object-store",
            TransportCounters {
                retries: 7,
                gave_up: 1,
                nack_suppressed: 4,
                reparents: 3,
                epoch: 9,
                cache_hits: 5,
                cache_misses: 2,
                origin_fetches: 2,
                conditional_not_modified: 6,
                ..Default::default()
            },
        );
        m.set_hop("object-store", 2);
        assert_eq!(m.rows().len(), 2);
        let row = &m.rows()[0];
        assert_eq!(row.transport, "in-proc");
        assert_eq!(row.hop, 0);
        assert_eq!(row.publishes, 2);
        assert_eq!(row.syncs, 1);
        assert_eq!(row.shard_refetches, 1);
        assert_eq!(row.counters.bytes_fetched, 512);
        assert_eq!(m.rows()[1].slow_paths, 1);
        assert_eq!(m.rows()[1].hop, 2);
        let dir = std::env::temp_dir().join(format!("pulse_transcsv_{}", std::process::id()));
        let p = dir.join("transport_plane.csv");
        m.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 3, "header + one row per backend");
        assert!(text.starts_with("transport,hop,"));
        assert!(text.lines().next().unwrap().ends_with(",reparents,epoch"));
        assert!(text.lines().nth(1).unwrap().starts_with("in-proc,0,2,1,2,"));
        assert!(text.lines().nth(1).unwrap().ends_with(",0,0"), "static backend: no failovers");
        let os = text.lines().nth(2).unwrap();
        assert!(os.starts_with("object-store,2,"));
        assert!(os.ends_with(",3,9"), "failover columns must round-trip: {}", os);
        // retries=7, gave_up=1, nack_suppressed=4 sit between
        // nacks_unserviceable and faults_injected
        assert!(os.contains(",7,1,4,0,"), "retry columns must round-trip: {}", os);
        // cache_hits=5, cache_misses=2, origin_fetches=2,
        // conditional_not_modified=6 sit between faults_injected and
        // shard_refetches
        assert!(os.contains(",0,5,2,2,6,0,"), "cache columns must round-trip: {}", os);
        assert!(
            text.lines().next().unwrap().contains(",retries,gave_up,nack_suppressed,"),
            "header must carry the retry columns"
        );
        assert!(
            text.lines().next().unwrap().contains(",cache_hits,cache_misses,origin_fetches,conditional_not_modified,"),
            "header must carry the store-plane cache columns"
        );
        // bytes_downloaded=2048, patches_applied=3, anchors_restored=1,
        // generation=2 sit between slow_paths=1 and reparents=3
        assert!(os.contains(",1,2048,3,1,2,3,9"), "sync-stats columns must round-trip: {}", os);
        assert!(
            text.lines().next().unwrap().contains(",bytes_downloaded,patches_applied,anchors_restored,generation,"),
            "header must carry the per-sync consumer columns"
        );
        assert!(
            text.lines().next().unwrap().contains(",markers_published,"),
            "header must carry the publish-marker column"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn obs_export_writes_one_row_per_histogram() {
        // The hub is process-global, so other tests may have recorded
        // samples already — assert presence and lower bounds only.
        crate::obs::hist(crate::obs::HistKind::NackRepair, 1_000);
        let dir = std::env::temp_dir().join(format!("pulse_obscsv_{}", std::process::id()));
        let p = dir.join("obs_hist.csv");
        ObsExport::new().write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 5, "header + one row per histogram: {text}");
        assert!(text.starts_with("hist,count,mean_us,p50_us,p99_us,p999_us,max_us\n"));
        for name in crate::obs::Obs::hist_names() {
            assert!(
                text.lines().any(|l| l.starts_with(&format!("{name},"))),
                "missing histogram row {name}: {text}"
            );
        }
        let nack = text.lines().find(|l| l.starts_with("nack_repair_us,")).unwrap();
        let count: u64 = nack.split(',').nth(1).unwrap().parse().unwrap();
        assert!(count >= 1, "recorded sample must land: {nack}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join(format!("pulse_csv_{}", std::process::id()));
        let p = dir.join("x/test.csv");
        let mut w = CsvWriter::create(&p, &["a", "b"]).unwrap();
        w.rowf(&[1.0, 2.5]).unwrap();
        w.row(&["x".into(), "y".into()]).unwrap();
        assert!(w.rowf(&[1.0]).is_err());
        drop(w);
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("a,b\n1,2.5\n"));
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
