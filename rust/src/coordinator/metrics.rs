//! CSV metrics emission for the paper harness (`results/*.csv`) — every
//! figure/table is regenerated from these files.

use anyhow::Result;
use std::io::Write;
use std::path::{Path, PathBuf};

pub struct CsvWriter {
    file: std::fs::File,
    pub path: PathBuf,
    n_cols: usize,
}

impl CsvWriter {
    /// Create (truncate) `path` with the given header columns.
    pub fn create(path: &Path, columns: &[&str]) -> Result<CsvWriter> {
        if let Some(p) = path.parent() {
            std::fs::create_dir_all(p)?;
        }
        let mut file = std::fs::File::create(path)?;
        writeln!(file, "{}", columns.join(","))?;
        Ok(CsvWriter { file, path: path.to_path_buf(), n_cols: columns.len() })
    }

    pub fn row(&mut self, values: &[String]) -> Result<()> {
        anyhow::ensure!(values.len() == self.n_cols, "row width mismatch");
        writeln!(self.file, "{}", values.join(","))?;
        Ok(())
    }

    pub fn rowf(&mut self, values: &[f64]) -> Result<()> {
        self.row(&values.iter().map(|v| format!("{v}")).collect::<Vec<_>>())
    }
}

/// Results directory: `$PULSE_RESULTS` or `<repo>/results`.
pub fn results_dir() -> PathBuf {
    if let Ok(d) = std::env::var("PULSE_RESULTS") {
        return PathBuf::from(d);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("results")
}

/// Render an aligned text table (paper-style rows) to stdout.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {} ==", title);
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!("{}", fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join(format!("pulse_csv_{}", std::process::id()));
        let p = dir.join("x/test.csv");
        let mut w = CsvWriter::create(&p, &["a", "b"]).unwrap();
        w.rowf(&[1.0, 2.5]).unwrap();
        w.row(&["x".into(), "y".into()]).unwrap();
        assert!(w.rowf(&[1.0]).is_err());
        drop(w);
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("a,b\n1,2.5\n"));
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
