//! Dense communication baselines (paper §5): DDP per-step gradient
//! all-reduce and the dense-DiLoCo variant (which lives in
//! [`crate::pulse::loco::OuterMethod::DiLoCo`]). This module provides
//! the DDP all-reduce plus the byte accounting used by Table 7 / Fig. 1.

/// Average gradients across R workers in place of worker 0's buffer —
/// a ring-all-reduce-equivalent result (exact mean, deterministic order).
pub fn allreduce_mean(grads: &mut [Vec<f32>]) {
    let r = grads.len();
    assert!(r > 0);
    let n = grads[0].len();
    for g in grads.iter() {
        assert_eq!(g.len(), n, "gradient length mismatch");
    }
    let (first, rest) = grads.split_at_mut(1);
    let acc = &mut first[0];
    for g in rest.iter() {
        for i in 0..n {
            acc[i] += g[i];
        }
    }
    let scale = 1.0 / r as f32;
    for v in acc.iter_mut() {
        *v *= scale;
    }
    // broadcast
    for g in rest.iter_mut() {
        g.copy_from_slice(acc);
    }
}

/// Per-worker bytes moved by one dense DDP step for an N-parameter
/// model: the logical payload accounting used in the paper (§F.3) —
/// one full FP32 gradient per worker per optimizer step.
pub fn ddp_bytes_per_step(n_params: u64) -> u64 {
    n_params * 4
}

/// DiLoCo per-worker payload per outer round: one full FP32
/// pseudo-gradient (§F.3: "N × 4 bytes per worker per outer round").
pub fn diloco_bytes_per_round(n_params: u64) -> u64 {
    n_params * 4
}

/// DDP bytes over one PULSELoCo outer-round window (H local steps):
/// H dense synchronizations (§F.3 "DDP comparison").
pub fn ddp_bytes_per_round(n_params: u64, h: u64) -> u64 {
    ddp_bytes_per_step(n_params) * h
}

/// Full-checkpoint weight synchronization bytes (BF16) — the dense
/// baseline for PULSESync (Fig. 1 left: 14 GB for a 7B model).
pub fn full_checkpoint_bytes(n_params: u64) -> u64 {
    n_params * 2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn allreduce_is_exact_mean() {
        let mut rng = Rng::new(3);
        let n = 1000;
        let grads: Vec<Vec<f32>> =
            (0..4).map(|_| (0..n).map(|_| rng.normal() as f32).collect()).collect();
        let expect: Vec<f32> =
            (0..n).map(|i| grads.iter().map(|g| g[i]).sum::<f32>() / 4.0).collect();
        let mut work = grads.clone();
        allreduce_mean(&mut work);
        for w in &work {
            for i in 0..n {
                assert!((w[i] - expect[i]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn byte_accounting_matches_paper_examples() {
        // 7B model: 62 GB BF16? No — paper: 14 GB BF16 for 7B, 30.46 GB
        // FP32 pseudo-gradient for 7.62B params.
        let n7b = 7_620_000_000u64;
        assert_eq!(full_checkpoint_bytes(7_000_000_000) / 1_000_000_000, 14);
        assert_eq!(diloco_bytes_per_round(n7b), 30_480_000_000);
        assert_eq!(ddp_bytes_per_round(n7b, 8), 8 * 30_480_000_000);
    }
}
