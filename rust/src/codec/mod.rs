//! Byte-stream codecs and integer coding used by the sparse patch
//! pipeline (paper §C, §H.2, §H.4).
//!
//! `lz4` and `snappy` are pure-Rust implementations of the real LZ4-block
//! and Snappy formats (the crates are absent from the offline image);
//! zstd and gzip wrap the vendored `zstd` / `flate2` crates. The
//! [`Codec`] enum is the paper's codec axis (Table 5).

pub mod delta;
pub mod lz4;
pub mod shuffle;
pub mod snappy;
pub mod varint;

use anyhow::Result;

/// General-purpose byte codecs evaluated in the paper (Table 5/12).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Codec {
    /// No entropy coding (raw sparse stream).
    None,
    Snappy,
    Lz4,
    Zstd1,
    Zstd3,
    Gzip6,
}

impl Codec {
    pub const ALL: [Codec; 5] = [Codec::Snappy, Codec::Lz4, Codec::Zstd1, Codec::Zstd3, Codec::Gzip6];

    pub fn name(&self) -> &'static str {
        match self {
            Codec::None => "none",
            Codec::Snappy => "snappy",
            Codec::Lz4 => "lz4",
            Codec::Zstd1 => "zstd-1",
            Codec::Zstd3 => "zstd-3",
            Codec::Gzip6 => "gzip-6",
        }
    }

    pub fn parse(s: &str) -> Result<Codec> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "none" | "raw" => Codec::None,
            "snappy" => Codec::Snappy,
            "lz4" => Codec::Lz4,
            "zstd-1" | "zstd1" | "zstd" => Codec::Zstd1,
            "zstd-3" | "zstd3" => Codec::Zstd3,
            "gzip-6" | "gzip" | "gzip6" => Codec::Gzip6,
            other => anyhow::bail!("unknown codec '{}'", other),
        })
    }

    /// Tag byte stored in patch containers.
    pub fn tag(&self) -> u8 {
        match self {
            Codec::None => 0,
            Codec::Snappy => 1,
            Codec::Lz4 => 2,
            Codec::Zstd1 => 3,
            Codec::Zstd3 => 4,
            Codec::Gzip6 => 5,
        }
    }

    pub fn from_tag(tag: u8) -> Result<Codec> {
        Ok(match tag {
            0 => Codec::None,
            1 => Codec::Snappy,
            2 => Codec::Lz4,
            3 => Codec::Zstd1,
            4 => Codec::Zstd3,
            5 => Codec::Gzip6,
            other => anyhow::bail!("unknown codec tag {}", other),
        })
    }

    pub fn compress(&self, data: &[u8]) -> Result<Vec<u8>> {
        Ok(match self {
            Codec::None => data.to_vec(),
            Codec::Snappy => snappy::compress(data),
            Codec::Lz4 => lz4::compress(data),
            Codec::Zstd1 => zstd::bulk::compress(data, 1)?,
            Codec::Zstd3 => zstd::bulk::compress(data, 3)?,
            Codec::Gzip6 => {
                use flate2::write::GzEncoder;
                use std::io::Write;
                let mut enc = GzEncoder::new(Vec::new(), flate2::Compression::new(6));
                enc.write_all(data)?;
                enc.finish()?
            }
        })
    }

    /// Decompress; `size_hint` is the expected decompressed size (stored
    /// in the container header) — required by the zstd bulk API.
    pub fn decompress(&self, data: &[u8], size_hint: usize) -> Result<Vec<u8>> {
        Ok(match self {
            Codec::None => data.to_vec(),
            Codec::Snappy => snappy::decompress(data)?,
            Codec::Lz4 => lz4::decompress(data, size_hint)?,
            Codec::Zstd1 | Codec::Zstd3 => zstd::bulk::decompress(data, size_hint.max(64))?,
            Codec::Gzip6 => {
                use flate2::read::GzDecoder;
                use std::io::Read;
                let mut out = Vec::with_capacity(size_hint);
                GzDecoder::new(data).read_to_end(&mut out)?;
                out
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn payloads() -> Vec<Vec<u8>> {
        let mut rng = crate::util::rng::Rng::new(31);
        vec![
            vec![],
            b"a".to_vec(),
            b"hello hello hello hello hello".to_vec(),
            vec![0u8; 10_000],
            (0..10_000u32).map(|i| (i % 251) as u8).collect(),
            (0..50_000).map(|_| rng.next_u32() as u8).collect(),
        ]
    }

    #[test]
    fn all_codecs_roundtrip() {
        for codec in Codec::ALL.iter().chain([&Codec::None]) {
            for p in payloads() {
                let c = codec.compress(&p).unwrap();
                let d = codec.decompress(&c, p.len()).unwrap();
                assert_eq!(d, p, "codec {} len {}", codec.name(), p.len());
            }
        }
    }

    #[test]
    fn compressible_data_shrinks() {
        let data = vec![7u8; 100_000];
        for codec in Codec::ALL {
            let c = codec.compress(&data).unwrap();
            assert!(c.len() < data.len() / 10, "{} -> {}", codec.name(), c.len());
        }
    }

    #[test]
    fn tags_roundtrip() {
        for codec in Codec::ALL.iter().chain([&Codec::None]) {
            assert_eq!(Codec::from_tag(codec.tag()).unwrap(), *codec);
        }
        assert!(Codec::from_tag(99).is_err());
    }

    #[test]
    fn parse_names() {
        assert_eq!(Codec::parse("zstd-1").unwrap(), Codec::Zstd1);
        assert_eq!(Codec::parse("LZ4").unwrap(), Codec::Lz4);
        assert!(Codec::parse("brotli").is_err());
    }

    #[test]
    fn prop_roundtrip_random() {
        crate::util::prop::check("codec roundtrip", 60, |g| {
            let n = g.len();
            let data = g.bytes(n);
            for codec in Codec::ALL {
                let c = codec.compress(&data).unwrap();
                let d = codec.decompress(&c, data.len()).unwrap();
                assert_eq!(d, data, "codec {}", codec.name());
            }
        });
    }
}
