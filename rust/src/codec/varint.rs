//! LEB128 varints and zig-zag coding — the index-stream packing used by
//! PULSELoCo's delta-varint payloads (paper §F.3) and the patch index
//! pipeline (§H.2).

/// Append `v` as an unsigned LEB128 varint.
#[inline]
pub fn put_uvarint(out: &mut Vec<u8>, mut v: u64) {
    while v >= 0x80 {
        out.push((v as u8 & 0x7F) | 0x80);
        v >>= 7;
    }
    out.push(v as u8);
}

/// Read an unsigned varint from `buf[*pos..]`, advancing `pos`.
#[inline]
pub fn get_uvarint(buf: &[u8], pos: &mut usize) -> anyhow::Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let b = *buf
            .get(*pos)
            .ok_or_else(|| anyhow::anyhow!("varint truncated at {}", pos))?;
        *pos += 1;
        if shift >= 64 {
            anyhow::bail!("varint overflow");
        }
        v |= ((b & 0x7F) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

/// Zig-zag encode a signed value (small magnitudes → small varints).
#[inline]
pub fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

/// Zig-zag decode.
#[inline]
pub fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

/// Number of bytes `v` occupies as a uvarint.
#[inline]
pub fn uvarint_len(v: u64) -> usize {
    if v == 0 {
        1
    } else {
        (64 - v.leading_zeros() as usize).div_ceil(7)
    }
}

/// Encode sorted indices as first-absolute + varint gaps — the
/// "delta-varint index" stream the paper's byte accounting uses (§F.3).
pub fn encode_sorted_indices(indices: &[u64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(indices.len() + 8);
    put_uvarint(&mut out, indices.len() as u64);
    let mut prev = 0u64;
    for (i, &idx) in indices.iter().enumerate() {
        if i == 0 {
            put_uvarint(&mut out, idx);
        } else {
            debug_assert!(idx > prev, "indices must be strictly increasing");
            put_uvarint(&mut out, idx - prev);
        }
        prev = idx;
    }
    out
}

/// Decode the stream produced by [`encode_sorted_indices`].
pub fn decode_sorted_indices(buf: &[u8], pos: &mut usize) -> anyhow::Result<Vec<u64>> {
    let n = get_uvarint(buf, pos)? as usize;
    let mut out = Vec::with_capacity(n);
    let mut prev = 0u64;
    for i in 0..n {
        let v = get_uvarint(buf, pos)?;
        let idx = if i == 0 { v } else { prev + v };
        out.push(idx);
        prev = idx;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uvarint_roundtrip_boundaries() {
        for v in [0u64, 1, 127, 128, 16383, 16384, u32::MAX as u64, u64::MAX] {
            let mut buf = Vec::new();
            put_uvarint(&mut buf, v);
            assert_eq!(buf.len(), uvarint_len(v), "v={}", v);
            let mut pos = 0;
            assert_eq!(get_uvarint(&buf, &mut pos).unwrap(), v);
            assert_eq!(pos, buf.len());
        }
    }

    #[test]
    fn zigzag_roundtrip() {
        for v in [0i64, 1, -1, 63, -64, i64::MAX, i64::MIN] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
        // small magnitudes stay small
        assert!(uvarint_len(zigzag(-3)) == 1);
    }

    #[test]
    fn truncated_varint_errors() {
        let buf = vec![0x80u8, 0x80];
        let mut pos = 0;
        assert!(get_uvarint(&buf, &mut pos).is_err());
    }

    #[test]
    fn sorted_indices_roundtrip() {
        crate::util::prop::check("sorted index roundtrip", 50, |g| {
            let n = g.len();
            let idx = g.sorted_indices(1 << 30, n);
            let buf = encode_sorted_indices(&idx);
            let mut pos = 0;
            let back = decode_sorted_indices(&buf, &mut pos).unwrap();
            assert_eq!(back, idx);
            assert_eq!(pos, buf.len());
        });
    }

    #[test]
    fn gap_compression_beats_absolute() {
        // dense gaps (mean ~16) → ~1 byte per index (paper §F.3)
        let idx: Vec<u64> = (0..100_000u64).map(|i| i * 16).collect();
        let buf = encode_sorted_indices(&idx);
        assert!(buf.len() < idx.len() * 2, "len={}", buf.len());
    }
}
