//! Index delta-encoding and type downscaling (paper §H.2, Table 10).
//!
//! The patch pipeline sorts indices, stores the first absolutely and the
//! rest as gaps, then narrows the integer type (u8 row deltas / u16 col
//! deltas for 2-D COO). These transforms contribute ≈23% compression on
//! top of the general-purpose codec (paper §4.2).

/// Delta-encode a sorted strictly-increasing u32 sequence in place:
/// out[0] = in[0], out[i] = in[i] - in[i-1].
pub fn delta_encode_u32(xs: &mut [u32]) {
    for i in (1..xs.len()).rev() {
        xs[i] -= xs[i - 1];
    }
}

/// Inverse of [`delta_encode_u32`] (prefix sum).
pub fn delta_decode_u32(xs: &mut [u32]) {
    for i in 1..xs.len() {
        xs[i] += xs[i - 1];
    }
}

/// Downscale width chosen for a delta stream (paper §H.4.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Width {
    U8,
    U16,
    U32,
}

impl Width {
    pub fn bytes(&self) -> usize {
        match self {
            Width::U8 => 1,
            Width::U16 => 2,
            Width::U32 => 4,
        }
    }

    pub fn tag(&self) -> u8 {
        match self {
            Width::U8 => 1,
            Width::U16 => 2,
            Width::U32 => 4,
        }
    }

    pub fn from_tag(tag: u8) -> anyhow::Result<Width> {
        Ok(match tag {
            1 => Width::U8,
            2 => Width::U16,
            4 => Width::U32,
            other => anyhow::bail!("bad width tag {}", other),
        })
    }
}

/// Narrowest width that can hold every value in `xs`.
pub fn pick_width(xs: &[u32]) -> Width {
    let max = xs.iter().copied().max().unwrap_or(0);
    if max <= u8::MAX as u32 {
        Width::U8
    } else if max <= u16::MAX as u32 {
        Width::U16
    } else {
        Width::U32
    }
}

/// Serialize `xs` at width `w` (little-endian).
pub fn pack(xs: &[u32], w: Width, out: &mut Vec<u8>) {
    match w {
        Width::U8 => out.extend(xs.iter().map(|&x| x as u8)),
        Width::U16 => {
            for &x in xs {
                out.extend_from_slice(&(x as u16).to_le_bytes());
            }
        }
        Width::U32 => {
            for &x in xs {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
}

/// Deserialize `n` values at width `w` from `buf[*pos..]`.
pub fn unpack(buf: &[u8], pos: &mut usize, n: usize, w: Width) -> anyhow::Result<Vec<u32>> {
    let need = n * w.bytes();
    if *pos + need > buf.len() {
        anyhow::bail!("unpack: truncated stream ({} needed, {} left)", need, buf.len() - *pos);
    }
    let mut out = Vec::with_capacity(n);
    match w {
        Width::U8 => out.extend(buf[*pos..*pos + n].iter().map(|&b| b as u32)),
        Width::U16 => {
            for c in buf[*pos..*pos + need].chunks_exact(2) {
                out.push(u16::from_le_bytes([c[0], c[1]]) as u32);
            }
        }
        Width::U32 => {
            for c in buf[*pos..*pos + need].chunks_exact(4) {
                out.push(u32::from_le_bytes([c[0], c[1], c[2], c[3]]));
            }
        }
    }
    *pos += need;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_roundtrip() {
        let orig: Vec<u32> = vec![3, 10, 11, 500, 1000];
        let mut xs = orig.clone();
        delta_encode_u32(&mut xs);
        assert_eq!(xs, vec![3, 7, 1, 489, 500]);
        delta_decode_u32(&mut xs);
        assert_eq!(xs, orig);
    }

    #[test]
    fn width_selection() {
        assert_eq!(pick_width(&[0, 255]), Width::U8);
        assert_eq!(pick_width(&[256]), Width::U16);
        assert_eq!(pick_width(&[70_000]), Width::U32);
        assert_eq!(pick_width(&[]), Width::U8);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        crate::util::prop::check("pack/unpack", 50, |g| {
            let n = g.len();
            let xs: Vec<u32> = (0..n).map(|_| g.rng.next_u32() >> (g.rng.below(24) as u32)).collect();
            let w = pick_width(&xs);
            let mut buf = Vec::new();
            pack(&xs, w, &mut buf);
            let mut pos = 0;
            let back = unpack(&buf, &mut pos, xs.len(), w).unwrap();
            assert_eq!(back, xs);
            assert_eq!(pos, buf.len());
        });
    }

    #[test]
    fn truncation_detected() {
        let mut buf = Vec::new();
        pack(&[1, 2, 3], Width::U16, &mut buf);
        buf.pop();
        let mut pos = 0;
        assert!(unpack(&buf, &mut pos, 3, Width::U16).is_err());
    }
}
