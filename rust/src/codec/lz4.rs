//! Pure-Rust LZ4 block-format codec (the `lz4_flex` crate is not in the
//! offline image). Implements the standard LZ4 block format with a
//! greedy hash-table matcher — the "speed end" codec of Table 5.

const MIN_MATCH: usize = 4;
const HASH_LOG: usize = 16;
const LAST_LITERALS: usize = 5;
/// Matches may not start within the last 12 bytes (format rule).
const MFLIMIT: usize = 12;

#[inline(always)]
fn hash(seq: u32) -> usize {
    (seq.wrapping_mul(2654435761) >> (32 - HASH_LOG)) as usize
}

#[inline(always)]
fn read_u32(b: &[u8], i: usize) -> u32 {
    u32::from_le_bytes([b[i], b[i + 1], b[i + 2], b[i + 3]])
}

/// Compress `src` into a standalone LZ4 block.
pub fn compress(src: &[u8]) -> Vec<u8> {
    let n = src.len();
    let mut out = Vec::with_capacity(n / 2 + 16);
    if n == 0 {
        out.push(0); // single empty-literal token
        return out;
    }
    if n < MFLIMIT + 1 {
        emit_sequence(&mut out, src, 0, n, None);
        return out;
    }
    let mut table = vec![0u32; 1 << HASH_LOG]; // position + 1 (0 = empty)
    let match_limit = n - LAST_LITERALS;
    let scan_limit = n - MFLIMIT;
    let mut anchor = 0usize;
    let mut i = 0usize;
    while i < scan_limit {
        let h = hash(read_u32(src, i));
        let cand = table[h] as usize;
        table[h] = (i + 1) as u32;
        if cand > 0 {
            let cand = cand - 1;
            let offset = i - cand;
            if offset <= 0xFFFF && read_u32(src, cand) == read_u32(src, i) {
                // extend match forward
                let mut len = MIN_MATCH;
                while i + len < match_limit && src[cand + len] == src[i + len] {
                    len += 1;
                }
                emit_sequence(&mut out, src, anchor, i - anchor, Some((offset as u16, len)));
                i += len;
                anchor = i;
                continue;
            }
        }
        i += 1;
    }
    // trailing literals
    emit_sequence(&mut out, src, anchor, n - anchor, None);
    out
}

fn emit_sequence(
    out: &mut Vec<u8>,
    src: &[u8],
    lit_start: usize,
    lit_len: usize,
    m: Option<(u16, usize)>,
) {
    let match_code = m.map(|(_, len)| len - MIN_MATCH);
    let token_lit = lit_len.min(15) as u8;
    let token_match = match_code.map(|c| c.min(15) as u8).unwrap_or(0);
    out.push((token_lit << 4) | token_match);
    if lit_len >= 15 {
        put_len(out, lit_len - 15);
    }
    out.extend_from_slice(&src[lit_start..lit_start + lit_len]);
    if let Some((offset, len)) = m {
        out.extend_from_slice(&offset.to_le_bytes());
        let code = len - MIN_MATCH;
        if code >= 15 {
            put_len(out, code - 15);
        }
    }
}

fn put_len(out: &mut Vec<u8>, mut extra: usize) {
    while extra >= 255 {
        out.push(255);
        extra -= 255;
    }
    out.push(extra as u8);
}

/// Decompress an LZ4 block. `expected_len` bounds the output (the block
/// format does not embed it; the container stores it).
pub fn decompress(src: &[u8], expected_len: usize) -> anyhow::Result<Vec<u8>> {
    let mut out: Vec<u8> = Vec::with_capacity(expected_len);
    let mut i = 0usize;
    loop {
        let token = *src.get(i).ok_or_else(|| anyhow::anyhow!("lz4: truncated token"))?;
        i += 1;
        // literals
        let mut lit_len = (token >> 4) as usize;
        if lit_len == 15 {
            lit_len += get_len(src, &mut i)?;
        }
        if i + lit_len > src.len() {
            anyhow::bail!("lz4: literal overrun");
        }
        out.extend_from_slice(&src[i..i + lit_len]);
        i += lit_len;
        if i == src.len() {
            break; // last sequence has no match part
        }
        // match
        if i + 2 > src.len() {
            anyhow::bail!("lz4: truncated offset");
        }
        let offset = u16::from_le_bytes([src[i], src[i + 1]]) as usize;
        i += 2;
        if offset == 0 {
            anyhow::bail!("lz4: zero offset");
        }
        let mut mlen = (token & 0x0F) as usize;
        if mlen == 15 {
            mlen += get_len(src, &mut i)?;
        }
        mlen += MIN_MATCH;
        let start = out
            .len()
            .checked_sub(offset)
            .ok_or_else(|| anyhow::anyhow!("lz4: offset {} beyond output", offset))?;
        // overlapping copy must be byte-by-byte
        for k in 0..mlen {
            let b = out[start + k];
            out.push(b);
        }
        if out.len() > expected_len {
            anyhow::bail!("lz4: output exceeds expected length");
        }
    }
    Ok(out)
}

fn get_len(src: &[u8], i: &mut usize) -> anyhow::Result<usize> {
    let mut total = 0usize;
    loop {
        let b = *src.get(*i).ok_or_else(|| anyhow::anyhow!("lz4: truncated length"))?;
        *i += 1;
        total += b as usize;
        if b != 255 {
            return Ok(total);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(data: &[u8]) {
        let c = compress(data);
        let d = decompress(&c, data.len()).unwrap();
        assert_eq!(d, data, "len={}", data.len());
    }

    #[test]
    fn empty_and_tiny() {
        roundtrip(b"");
        roundtrip(b"a");
        roundtrip(b"abcdefgh");
        roundtrip(b"aaaaaaaaaaaa");
    }

    #[test]
    fn repetitive_compresses() {
        let data = vec![42u8; 100_000];
        let c = compress(&data);
        assert!(c.len() < 1000, "c.len()={}", c.len());
        assert_eq!(decompress(&c, data.len()).unwrap(), data);
    }

    #[test]
    fn structured_data() {
        let mut data = Vec::new();
        for i in 0..10_000u32 {
            data.extend_from_slice(&(i / 7).to_le_bytes());
        }
        let c = compress(&data);
        assert!(c.len() < data.len() / 2);
        assert_eq!(decompress(&c, data.len()).unwrap(), data);
    }

    #[test]
    fn incompressible_expands_gracefully() {
        let mut rng = crate::util::rng::Rng::new(77);
        let data: Vec<u8> = (0..65_536).map(|_| rng.next_u32() as u8).collect();
        let c = compress(&data);
        assert!(c.len() < data.len() + data.len() / 128 + 32);
        assert_eq!(decompress(&c, data.len()).unwrap(), data);
    }

    #[test]
    fn overlapping_matches() {
        // "abcabcabc..." forces offset < match length (RLE-like copies)
        let data: Vec<u8> = b"abc".iter().cycle().take(9999).copied().collect();
        roundtrip(&data);
    }

    #[test]
    fn corrupt_input_errors_not_panics() {
        let c = compress(b"hello hello hello hello hello hello");
        for cut in 1..c.len() {
            let _ = decompress(&c[..cut], 100); // must not panic
        }
        let _ = decompress(&[0xF0, 0x01], 100);
    }

    #[test]
    fn prop_roundtrip() {
        crate::util::prop::check("lz4 roundtrip", 80, |g| {
            let n = g.len() * 8;
            let data = g.bytes(n);
            let c = compress(&data);
            let d = decompress(&c, data.len()).unwrap();
            assert_eq!(d, data);
        });
    }
}
