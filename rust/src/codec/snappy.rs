//! Pure-Rust Snappy codec (the `snap` crate is not in the offline
//! image). Implements the standard Snappy raw format: uvarint length
//! preamble, then literal / copy-1 / copy-2 tags, with a greedy
//! hash-table matcher. Paper Table 5's fastest-encode codec.

use super::varint::{get_uvarint, put_uvarint};

const MIN_MATCH: usize = 4;
const HASH_LOG: usize = 15;

#[inline(always)]
fn hash(seq: u32) -> usize {
    (seq.wrapping_mul(0x1e35a7bd) >> (32 - HASH_LOG)) as usize
}

#[inline(always)]
fn read_u32(b: &[u8], i: usize) -> u32 {
    u32::from_le_bytes([b[i], b[i + 1], b[i + 2], b[i + 3]])
}

/// Compress `src` in Snappy raw format.
pub fn compress(src: &[u8]) -> Vec<u8> {
    let n = src.len();
    let mut out = Vec::with_capacity(n / 2 + 16);
    put_uvarint(&mut out, n as u64);
    if n == 0 {
        return out;
    }
    if n < MIN_MATCH + 4 {
        emit_literal(&mut out, src);
        return out;
    }
    let mut table = vec![0u32; 1 << HASH_LOG];
    let limit = n - 4; // need 4 bytes to hash
    let mut anchor = 0usize;
    let mut i = 0usize;
    while i < limit {
        let h = hash(read_u32(src, i));
        let cand = table[h] as usize;
        table[h] = (i + 1) as u32;
        if cand > 0 {
            let cand = cand - 1;
            let offset = i - cand;
            if offset <= 0xFFFF && read_u32(src, cand) == read_u32(src, i) {
                let mut len = MIN_MATCH;
                while i + len < n && src[cand + len] == src[i + len] {
                    len += 1;
                }
                if anchor < i {
                    emit_literal(&mut out, &src[anchor..i]);
                }
                emit_copy(&mut out, offset, len);
                i += len;
                anchor = i;
                continue;
            }
        }
        i += 1;
    }
    if anchor < n {
        emit_literal(&mut out, &src[anchor..n]);
    }
    out
}

fn emit_literal(out: &mut Vec<u8>, lit: &[u8]) {
    let mut rest = lit;
    while !rest.is_empty() {
        let take = rest.len().min(1 << 24); // keep extension ≤ 3 bytes
        let n = take - 1;
        if n < 60 {
            out.push((n as u8) << 2);
        } else if n < 256 {
            out.push(60 << 2);
            out.push(n as u8);
        } else if n < 65536 {
            out.push(61 << 2);
            out.extend_from_slice(&(n as u16).to_le_bytes());
        } else {
            out.push(62 << 2);
            out.extend_from_slice(&(n as u32).to_le_bytes()[..3]);
        }
        out.extend_from_slice(&rest[..take]);
        rest = &rest[take..];
    }
}

fn emit_copy(out: &mut Vec<u8>, offset: usize, mut len: usize) {
    // copy-2 handles len 1..=64; split longer matches.
    while len > 64 {
        emit_copy2(out, offset, 64);
        len -= 64;
    }
    if len >= 4 && len <= 11 && offset < 2048 {
        // copy-1: len 4..=11, offset < 2^11
        out.push(0b01 | (((len - 4) as u8) << 2) | (((offset >> 8) as u8) << 5));
        out.push(offset as u8);
    } else {
        emit_copy2(out, offset, len);
    }
}

fn emit_copy2(out: &mut Vec<u8>, offset: usize, len: usize) {
    debug_assert!((1..=64).contains(&len) && offset <= 0xFFFF);
    out.push(0b10 | (((len - 1) as u8) << 2));
    out.extend_from_slice(&(offset as u16).to_le_bytes());
}

/// Decompress a Snappy raw buffer.
pub fn decompress(src: &[u8]) -> anyhow::Result<Vec<u8>> {
    let mut pos = 0usize;
    let expect = get_uvarint(src, &mut pos)? as usize;
    let mut out: Vec<u8> = Vec::with_capacity(expect);
    while pos < src.len() {
        let tag = src[pos];
        pos += 1;
        match tag & 0b11 {
            0b00 => {
                // literal
                let code = (tag >> 2) as usize;
                let len = if code < 60 {
                    code + 1
                } else {
                    let nbytes = code - 59;
                    if pos + nbytes > src.len() {
                        anyhow::bail!("snappy: truncated literal length");
                    }
                    let mut v = 0usize;
                    for k in 0..nbytes {
                        v |= (src[pos + k] as usize) << (8 * k);
                    }
                    pos += nbytes;
                    v + 1
                };
                if pos + len > src.len() {
                    anyhow::bail!("snappy: literal overrun");
                }
                out.extend_from_slice(&src[pos..pos + len]);
                pos += len;
            }
            0b01 => {
                // copy-1
                if pos >= src.len() {
                    anyhow::bail!("snappy: truncated copy-1");
                }
                let len = 4 + ((tag >> 2) & 0x7) as usize;
                let offset = (((tag >> 5) as usize) << 8) | src[pos] as usize;
                pos += 1;
                copy(&mut out, offset, len)?;
            }
            0b10 => {
                // copy-2
                if pos + 2 > src.len() {
                    anyhow::bail!("snappy: truncated copy-2");
                }
                let len = 1 + (tag >> 2) as usize;
                let offset = u16::from_le_bytes([src[pos], src[pos + 1]]) as usize;
                pos += 2;
                copy(&mut out, offset, len)?;
            }
            _ => {
                // copy-4 (we never emit it, but decode for completeness)
                if pos + 4 > src.len() {
                    anyhow::bail!("snappy: truncated copy-4");
                }
                let len = 1 + (tag >> 2) as usize;
                let offset =
                    u32::from_le_bytes([src[pos], src[pos + 1], src[pos + 2], src[pos + 3]])
                        as usize;
                pos += 4;
                copy(&mut out, offset, len)?;
            }
        }
        if out.len() > expect {
            anyhow::bail!("snappy: output exceeds declared length");
        }
    }
    if out.len() != expect {
        anyhow::bail!("snappy: output length {} != declared {}", out.len(), expect);
    }
    Ok(out)
}

fn copy(out: &mut Vec<u8>, offset: usize, len: usize) -> anyhow::Result<()> {
    if offset == 0 || offset > out.len() {
        anyhow::bail!("snappy: bad offset {} (output {})", offset, out.len());
    }
    let start = out.len() - offset;
    for k in 0..len {
        let b = out[start + k];
        out.push(b);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(data: &[u8]) {
        let c = compress(data);
        let d = decompress(&c).unwrap();
        assert_eq!(d, data, "len={}", data.len());
    }

    #[test]
    fn empty_and_tiny() {
        roundtrip(b"");
        roundtrip(b"x");
        roundtrip(b"abcdefg");
    }

    #[test]
    fn repetitive() {
        // Snappy's copy tags cap match length at 64, so an all-equal
        // buffer costs ~3 bytes per 64 (unlike LZ4's run extension).
        let data = vec![9u8; 100_000];
        let c = compress(&data);
        assert!(c.len() < 100_000 * 3 / 64 + 200, "c.len()={}", c.len());
        assert_eq!(decompress(&c).unwrap(), data);
    }

    #[test]
    fn long_literals() {
        // incompressible run > 60 bytes exercises multi-byte literal tags
        let mut rng = crate::util::rng::Rng::new(13);
        for n in [61, 257, 70_000] {
            let data: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
            roundtrip(&data);
        }
    }

    #[test]
    fn overlapping_copies() {
        let data: Vec<u8> = b"ab".iter().cycle().take(5000).copied().collect();
        roundtrip(&data);
    }

    #[test]
    fn corrupt_never_panics() {
        let c = compress(b"some compressible data data data data");
        for cut in 1..c.len() {
            let _ = decompress(&c[..cut]);
        }
    }

    #[test]
    fn prop_roundtrip() {
        crate::util::prop::check("snappy roundtrip", 80, |g| {
            let n = g.len() * 8;
            let data = g.bytes(n);
            roundtrip(&data);
        });
    }
}
