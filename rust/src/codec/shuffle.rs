//! Byte-shuffle transform (paper §F.3: "byte-shuffle plus zstd-3").
//!
//! Transposes an array of fixed-width elements so that byte-plane 0 of
//! every element is contiguous, then plane 1, etc. FP32 values with
//! similar magnitudes share exponent bytes, so shuffling groups highly
//! compressible planes together before the byte codec.

/// Shuffle `data` (length divisible by `width`) into byte planes.
pub fn shuffle(data: &[u8], width: usize) -> Vec<u8> {
    assert!(width > 0 && data.len() % width == 0, "len {} % width {}", data.len(), width);
    let n = data.len() / width;
    let mut out = vec![0u8; data.len()];
    for plane in 0..width {
        let dst = &mut out[plane * n..(plane + 1) * n];
        for (i, d) in dst.iter_mut().enumerate() {
            *d = data[i * width + plane];
        }
    }
    out
}

/// Inverse of [`shuffle`].
pub fn unshuffle(data: &[u8], width: usize) -> Vec<u8> {
    assert!(width > 0 && data.len() % width == 0);
    let n = data.len() / width;
    let mut out = vec![0u8; data.len()];
    for plane in 0..width {
        let src = &data[plane * n..(plane + 1) * n];
        for (i, &s) in src.iter().enumerate() {
            out[i * width + plane] = s;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        crate::util::prop::check("shuffle roundtrip", 40, |g| {
            for width in [1usize, 2, 4, 8] {
                let n = g.len();
                let data = g.bytes(n - n % width);
                assert_eq!(unshuffle(&shuffle(&data, width), width), data);
            }
        });
    }

    #[test]
    fn improves_f32_compression() {
        // Similar-magnitude f32s compress better shuffled.
        let mut rng = crate::util::rng::Rng::new(41);
        let mut raw = Vec::new();
        for _ in 0..20_000 {
            let v = 0.01f32 + 0.001 * rng.f32();
            raw.extend_from_slice(&v.to_le_bytes());
        }
        let plain = zstd::bulk::compress(&raw, 3).unwrap();
        let shuf = zstd::bulk::compress(&shuffle(&raw, 4), 3).unwrap();
        assert!(
            (shuf.len() as f64) < (plain.len() as f64) * 0.95,
            "shuffled {} vs plain {}",
            shuf.len(),
            plain.len()
        );
    }
}
