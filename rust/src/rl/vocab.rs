//! Shared token vocabulary for the synthetic tasks (64 symbols, matching
//! the model zoo's vocab).
//!
//! Layout:
//!   0 PAD, 1 BOS, 2 EOS, 3 THINK, 4 EQ ('='),
//!   5–14 digits 0–9,
//!   15 PLUS, 16 MINUS, 17 TIMES, 18 MOD,
//!   19 SEP (example separator), 20 ARROW ('→' in I/O examples),
//!   21–30 PUSH0–PUSH9 (stack-VM immediates),
//!   31 ADD, 32 SUB, 33 MUL, 34 DUP, 35 SWAP, 36 IN, 37 END.
//! Remaining ids up to 63 are unused (reserved).

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const THINK: i32 = 3;
pub const EQ: i32 = 4;
pub const DIGIT0: i32 = 5; // .. DIGIT0+9
pub const PLUS: i32 = 15;
pub const MINUS: i32 = 16;
pub const TIMES: i32 = 17;
pub const MOD: i32 = 18;
pub const SEP: i32 = 19;
pub const ARROW: i32 = 20;
pub const PUSH0: i32 = 21; // .. PUSH0+9
pub const OP_ADD: i32 = 31;
pub const OP_SUB: i32 = 32;
pub const OP_MUL: i32 = 33;
pub const OP_DUP: i32 = 34;
pub const OP_SWAP: i32 = 35;
pub const OP_IN: i32 = 36;
pub const OP_END: i32 = 37;

pub const VOCAB: usize = 64;

pub fn digit(d: u8) -> i32 {
    debug_assert!(d < 10);
    DIGIT0 + d as i32
}

pub fn as_digit(tok: i32) -> Option<u8> {
    if (DIGIT0..DIGIT0 + 10).contains(&tok) {
        Some((tok - DIGIT0) as u8)
    } else {
        None
    }
}

/// Encode a non-negative number as digit tokens (most significant
/// first).
pub fn encode_number(mut n: u64, out: &mut Vec<i32>) {
    let mut digits = Vec::new();
    loop {
        digits.push((n % 10) as u8);
        n /= 10;
        if n == 0 {
            break;
        }
    }
    for &d in digits.iter().rev() {
        out.push(digit(d));
    }
}

/// Human-readable rendering (debugging / logs).
pub fn detokenize(tokens: &[i32]) -> String {
    tokens
        .iter()
        .map(|&t| match t {
            PAD => "_".to_string(),
            BOS => "<s>".to_string(),
            EOS => "</s>".to_string(),
            THINK => "…".to_string(),
            EQ => "=".to_string(),
            PLUS => "+".to_string(),
            MINUS => "-".to_string(),
            TIMES => "*".to_string(),
            MOD => "%".to_string(),
            SEP => ";".to_string(),
            ARROW => "→".to_string(),
            t if as_digit(t).is_some() => as_digit(t).unwrap().to_string(),
            t if (PUSH0..PUSH0 + 10).contains(&t) => format!("P{}", t - PUSH0),
            OP_ADD => "ADD".to_string(),
            OP_SUB => "SUB".to_string(),
            OP_MUL => "MUL".to_string(),
            OP_DUP => "DUP".to_string(),
            OP_SWAP => "SWAP".to_string(),
            OP_IN => "IN".to_string(),
            OP_END => "END".to_string(),
            other => format!("?{}", other),
        })
        .collect::<Vec<_>>()
        .join(" ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn number_encoding() {
        let mut v = Vec::new();
        encode_number(0, &mut v);
        assert_eq!(v, vec![digit(0)]);
        v.clear();
        encode_number(407, &mut v);
        assert_eq!(v, vec![digit(4), digit(0), digit(7)]);
    }

    #[test]
    fn all_tokens_fit_vocab() {
        for t in [PAD, BOS, EOS, THINK, EQ, PLUS, MINUS, TIMES, MOD, SEP, ARROW, OP_END] {
            assert!((t as usize) < VOCAB);
        }
        assert!(((PUSH0 + 9) as usize) < VOCAB);
    }

    #[test]
    fn detokenize_is_total() {
        let s = detokenize(&(0..VOCAB as i32).collect::<Vec<_>>());
        assert!(s.contains("</s>") && s.contains("END"));
    }
}
