//! Reinforcement-learning substrate: GRPO with verifiable rewards
//! (RLVR, paper §2) over synthetic tasks.
//!
//! * [`vocab`] — the shared token vocabulary for both tasks.
//! * [`tasks`] — the MATH stand-in (modular arithmetic with verifiable
//!   final answers) and the MBPP stand-in (stack-VM program synthesis
//!   verified by unit tests via [`svm`]).
//! * [`grpo`] — group-relative advantages, rollout batching, masking,
//!   pass@1 evaluation.
//! * [`svm`] — the stack-machine substrate the code task executes on.

pub mod grpo;
pub mod svm;
pub mod tasks;
pub mod vocab;

/// Composite reward breakdown (paper Eq. 21/22).
#[derive(Debug, Clone, Copy, Default)]
pub struct Reward {
    /// Correctness / test-pass component in [0,1].
    pub correct: f64,
    /// Answer/solution format component in [0,1].
    pub format: f64,
    /// Thinking-presence component in [0,1].
    pub thinking: f64,
    /// Fourth component: no-trailing (math) or syntax validity (code).
    pub extra: f64,
    /// Weighted total.
    pub total: f64,
}

/// One verifiable problem instance handed from task to verifier.
#[derive(Debug, Clone)]
pub enum Instance {
    /// Modular-arithmetic: expected answer digits (most-significant
    /// first).
    Math { answer: Vec<u8> },
    /// Program synthesis: unit tests as (input, expected output).
    Code { tests: Vec<(i64, i64)> },
}

/// A verifiable-reward task: generates prompts and scores completions.
pub trait Task: Send + Sync {
    fn name(&self) -> &'static str;
    /// Sample a problem; returns (prompt tokens of length P, instance).
    fn sample(&self, prompt_len: usize, rng: &mut crate::util::rng::Rng)
        -> (Vec<i32>, Instance);
    /// Score a completion (the G generated tokens).
    fn reward(&self, instance: &Instance, completion: &[i32]) -> Reward;
}
