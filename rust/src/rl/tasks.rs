//! Verifiable-reward tasks (paper §F.5).
//!
//! * [`MathTask`] — MATH stand-in: modular arithmetic `a ⊕ b (mod m)`
//!   with an exact-match final answer and the composite reward of
//!   Eq. 21: 0.7·correct + 0.15·format + 0.1·thinking + 0.05·no-trailing.
//! * [`CodeTask`] — MBPP stand-in: the prompt shows I/O examples for a
//!   hidden stack-VM function; the completion is a program; reward per
//!   Eq. 22: 0.7·pass-rate + 0.1·syntax + 0.1·format + 0.1·thinking.

use super::svm;
use super::vocab::*;
use super::{Instance, Reward, Task};
use crate::util::rng::Rng;

/// Completion convention shared by both tasks:
///   [THINK]* answer-tokens EOS PAD*
/// "thinking" credit = at least one THINK token before the answer.
fn split_completion(completion: &[i32]) -> (usize, Option<usize>) {
    // returns (#leading THINK tokens, index of first EOS if any)
    let think = completion.iter().take_while(|&&t| t == THINK).count();
    let eos = completion.iter().position(|&t| t == EOS);
    (think, eos)
}

fn no_trailing_after_eos(completion: &[i32], eos: Option<usize>) -> bool {
    match eos {
        None => false,
        Some(i) => completion[i + 1..].iter().all(|&t| t == PAD),
    }
}

// ------------------------------------------------------------------ math

/// Modular arithmetic with verifiable single/multi-digit answers.
pub struct MathTask {
    /// Operand range [0, max_operand].
    pub max_operand: u64,
    /// Answer modulus (keeps answers ≤ 2 digits so they fit G=8).
    pub modulus: u64,
}

impl Default for MathTask {
    fn default() -> Self {
        MathTask { max_operand: 99, modulus: 100 }
    }
}

impl Task for MathTask {
    fn name(&self) -> &'static str {
        "math"
    }

    /// Prompt: BOS a-digits op b-digits MOD m-digits EQ PAD*  (length P).
    fn sample(&self, prompt_len: usize, rng: &mut Rng) -> (Vec<i32>, Instance) {
        let a = rng.below(self.max_operand + 1);
        let b = rng.below(self.max_operand + 1);
        let m = self.modulus as i64;
        let (op_tok, result) = match rng.below(3) {
            0 => (PLUS, (a + b) as i64),
            1 => (MINUS, a as i64 - b as i64),
            _ => (TIMES, (a * b) as i64),
        };
        let result = (result.rem_euclid(m)) as u64;
        let mut prompt = vec![BOS];
        encode_number(a, &mut prompt);
        prompt.push(op_tok);
        encode_number(b, &mut prompt);
        prompt.push(MOD);
        encode_number(self.modulus, &mut prompt);
        prompt.push(EQ);
        assert!(prompt.len() <= prompt_len, "prompt overflows P");
        prompt.resize(prompt_len, PAD);
        let mut answer = Vec::new();
        encode_number(result, &mut answer);
        let answer: Vec<u8> = answer.iter().map(|&t| as_digit(t).unwrap()).collect();
        (prompt, Instance::Math { answer })
    }

    fn reward(&self, instance: &Instance, completion: &[i32]) -> Reward {
        let Instance::Math { answer } = instance else {
            panic!("MathTask got non-math instance")
        };
        let (think, eos) = split_completion(completion);
        // digits between the THINK prefix and EOS (or end)
        let upto = eos.unwrap_or(completion.len());
        let digits: Vec<u8> =
            completion[think..upto].iter().filter_map(|&t| as_digit(t)).collect();
        let all_digits = completion[think..upto].iter().all(|&t| as_digit(t).is_some());
        let correct = if &digits == answer && all_digits { 1.0 } else { 0.0 };
        let format = if eos.is_some() && all_digits { 1.0 } else { 0.0 };
        let thinking = if think > 0 { 1.0 } else { 0.0 };
        let extra = if no_trailing_after_eos(completion, eos) { 1.0 } else { 0.0 };
        let total = 0.7 * correct + 0.15 * format + 0.1 * thinking + 0.05 * extra;
        Reward { correct, format, thinking, extra, total }
    }
}

// ------------------------------------------------------------------ code

/// Stack-VM program synthesis from I/O examples.
pub struct CodeTask {
    programs: Vec<(&'static str, Vec<i32>)>,
    /// Tests per problem (shown + hidden).
    pub n_tests: usize,
}

impl Default for CodeTask {
    fn default() -> Self {
        CodeTask { programs: svm::reference_programs(), n_tests: 4 }
    }
}

impl Task for CodeTask {
    fn name(&self) -> &'static str {
        "code"
    }

    /// Prompt: BOS x1 ARROW y1 SEP x2 ARROW y2 SEP EQ PAD* — two worked
    /// examples of the hidden function; the model must emit a program.
    fn sample(&self, prompt_len: usize, rng: &mut Rng) -> (Vec<i32>, Instance) {
        let (_, prog) = &self.programs[rng.below(self.programs.len() as u64) as usize];
        // sample distinct small inputs so numbers stay ≤ 2 digits
        let mut tests = Vec::new();
        let mut used = std::collections::BTreeSet::new();
        while tests.len() < self.n_tests {
            let x = rng.below(10) as i64;
            if !used.insert(x) {
                continue;
            }
            let y = svm::run(prog, x).expect("reference program must run");
            tests.push((x, y));
        }
        let mut prompt = vec![BOS];
        for (i, (x, y)) in tests.iter().take(2).enumerate() {
            if i > 0 {
                prompt.push(SEP);
            }
            encode_number(*x as u64, &mut prompt);
            prompt.push(ARROW);
            // outputs can exceed 2 digits (e.g. 9² = 81, fits);
            // reference programs keep |y| < 100 for x < 10
            encode_number((*y).unsigned_abs(), &mut prompt);
        }
        prompt.push(EQ);
        assert!(prompt.len() <= prompt_len, "prompt overflows P");
        prompt.resize(prompt_len, PAD);
        (prompt, Instance::Code { tests })
    }

    fn reward(&self, instance: &Instance, completion: &[i32]) -> Reward {
        let Instance::Code { tests } = instance else {
            panic!("CodeTask got non-code instance")
        };
        let (think, eos) = split_completion(completion);
        let upto = eos.unwrap_or(completion.len());
        let program = &completion[think..upto];
        let correct = svm::pass_rate(program, tests);
        let syntax = if svm::is_syntactically_valid(program) { 1.0 } else { 0.0 };
        let format = if eos.is_some() || program.contains(&OP_END) { 1.0 } else { 0.0 };
        let thinking = if think > 0 { 1.0 } else { 0.0 };
        let total = 0.7 * correct + 0.1 * syntax + 0.1 * format + 0.1 * thinking;
        Reward { correct, format, thinking, extra: syntax, total }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn math_prompt_fits_and_answer_verifies() {
        let task = MathTask::default();
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let (prompt, inst) = task.sample(16, &mut rng);
            assert_eq!(prompt.len(), 16);
            assert_eq!(prompt[0], BOS);
            let Instance::Math { answer } = &inst else { unreachable!() };
            assert!(!answer.is_empty() && answer.len() <= 2);
            // a perfect completion scores 1.0
            let mut completion = vec![THINK];
            for &d in answer {
                completion.push(digit(d));
            }
            completion.push(EOS);
            completion.resize(8, PAD);
            let r = task.reward(&inst, &completion);
            assert!((r.total - 1.0).abs() < 1e-12, "{:?}", r);
        }
    }

    #[test]
    fn math_partial_credit() {
        let task = MathTask::default();
        let inst = Instance::Math { answer: vec![4, 2] };
        // wrong answer, good format
        let r = task.reward(&inst, &[THINK, digit(4), digit(3), EOS, PAD, PAD, PAD, PAD]);
        assert_eq!(r.correct, 0.0);
        assert_eq!(r.format, 1.0);
        assert!((r.total - 0.3).abs() < 1e-12);
        // right answer, no EOS (format + trailing fail)
        let r2 = task.reward(&inst, &[digit(4), digit(2), PAD, PAD, PAD, PAD, PAD, PAD]);
        assert_eq!(r2.format, 0.0);
        // digits parse ignores PADs → correctness still granted? No:
        // all_digits over [think..end] fails because PADs are not digits.
        assert_eq!(r2.correct, 0.0);
        // garbage
        let r3 = task.reward(&inst, &[PLUS; 8]);
        assert_eq!(r3.total, 0.0);
    }

    #[test]
    fn code_reward_grades_pass_rate() {
        let task = CodeTask::default();
        let inst = Instance::Code { tests: vec![(2, 4), (3, 9), (5, 25), (7, 49)] };
        use super::super::vocab::*;
        // perfect: THINK IN DUP MUL END EOS
        let perfect = vec![THINK, OP_IN, OP_DUP, OP_MUL, OP_END, EOS, PAD, PAD];
        let r = task.reward(&inst, &perfect);
        assert!((r.total - 1.0).abs() < 1e-12, "{:?}", r);
        // wrong but valid program: identity
        let wrong = vec![OP_IN, OP_END, EOS, PAD, PAD, PAD, PAD, PAD];
        let r2 = task.reward(&inst, &wrong);
        assert_eq!(r2.correct, 0.0);
        assert_eq!(r2.extra, 1.0); // syntax
        // garbage
        let r3 = task.reward(&inst, &[EQ; 8]);
        assert_eq!(r3.correct, 0.0);
        assert_eq!(r3.extra, 0.0);
    }

    #[test]
    fn code_prompts_verifiable_by_reference() {
        let task = CodeTask::default();
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            let (prompt, inst) = task.sample(16, &mut rng);
            assert_eq!(prompt.len(), 16);
            let Instance::Code { tests } = &inst else { unreachable!() };
            assert_eq!(tests.len(), 4);
            // at least one reference program passes all tests
            let some_pass = svm::reference_programs()
                .iter()
                .any(|(_, p)| svm::pass_rate(p, tests) == 1.0);
            assert!(some_pass);
        }
    }
}
