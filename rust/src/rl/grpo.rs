//! GRPO batch construction (paper §2, §H.1): sample a group of G
//! responses per prompt, compute group-relative advantages
//! Â_i = (r_i − µ_G)/σ_G, build the completion mask, and evaluate
//! pass@1 with greedy decoding.

use super::{Instance, Task};
use crate::runtime::ModelRuntime;
use crate::util::rng::Rng;
use anyhow::Result;

/// GRPO hyperparameters (paper Table 8, scaled to this testbed).
#[derive(Debug, Clone, Copy)]
pub struct GrpoConfig {
    /// Rollouts per prompt (the group size G).
    pub group: usize,
    /// Sampling temperature for training rollouts.
    pub temperature: f32,
    /// σ floor to avoid division blow-ups on constant-reward groups.
    pub sigma_floor: f64,
}

impl Default for GrpoConfig {
    fn default() -> Self {
        GrpoConfig { group: 8, temperature: 1.0, sigma_floor: 1e-4 }
    }
}

/// One training batch: everything the grad graph consumes.
#[derive(Debug, Clone)]
pub struct Batch {
    /// [B, T] tokens (prompts + completions).
    pub tokens: Vec<i32>,
    /// [B, G] behaviour-policy logprobs from the rollout.
    pub old_logprobs: Vec<f32>,
    /// [B] group-relative advantages.
    pub advantages: Vec<f32>,
    /// [B, G] completion mask (1 up to and including first EOS).
    pub mask: Vec<f32>,
    /// [B] raw composite rewards.
    pub rewards: Vec<f64>,
    /// Mean composite reward over the batch.
    pub mean_reward: f64,
    /// Fraction of rollouts with full correctness.
    pub correct_rate: f64,
}

/// Sample prompts: B/G distinct problems, each repeated G times
/// (row-major [B, P]). Returns (prompts, instances per row).
pub fn sample_prompts(
    task: &dyn Task,
    batch: usize,
    prompt_len: usize,
    group: usize,
    rng: &mut Rng,
) -> (Vec<i32>, Vec<Instance>) {
    assert!(batch % group == 0, "batch {} not divisible by group {}", batch, group);
    let n_problems = batch / group;
    let mut prompts = Vec::with_capacity(batch * prompt_len);
    let mut instances = Vec::with_capacity(batch);
    for _ in 0..n_problems {
        let (p, inst) = task.sample(prompt_len, rng);
        for _ in 0..group {
            prompts.extend_from_slice(&p);
            instances.push(inst.clone());
        }
    }
    (prompts, instances)
}

/// Compute the completion mask row: 1.0 for positions up to and
/// including the first EOS (all G if none).
pub fn completion_mask(completion: &[i32]) -> Vec<f32> {
    let eos = completion.iter().position(|&t| t == super::vocab::EOS);
    let upto = eos.map(|i| i + 1).unwrap_or(completion.len());
    (0..completion.len()).map(|i| if i < upto { 1.0 } else { 0.0 }).collect()
}

/// Group-relative advantages (paper Eq. 25).
pub fn group_advantages(rewards: &[f64], group: usize, sigma_floor: f64) -> Vec<f32> {
    assert!(rewards.len() % group == 0);
    let mut adv = Vec::with_capacity(rewards.len());
    for chunk in rewards.chunks(group) {
        let mu = chunk.iter().sum::<f64>() / group as f64;
        let var = chunk.iter().map(|r| (r - mu) * (r - mu)).sum::<f64>() / group as f64;
        let sigma = var.sqrt().max(sigma_floor);
        for &r in chunk {
            adv.push(((r - mu) / sigma) as f32);
        }
    }
    adv
}

/// Generate one full GRPO batch through the runtime. `flat` is the
/// *rollout policy's* parameter vector (the BF16 view the inference
/// worker serves; the trainer passes its masters when on-policy).
pub fn generate_batch(
    rt: &ModelRuntime,
    flat: &[f32],
    task: &dyn Task,
    cfg: GrpoConfig,
    rng: &mut Rng,
) -> Result<Batch> {
    let d = rt.manifest.dims.clone();
    let (prompts, instances) = sample_prompts(task, d.batch, d.prompt_len, cfg.group, rng);
    let key = [rng.next_u32(), rng.next_u32()];
    let ro = rt.rollout(flat, &prompts, key, cfg.temperature)?;
    build_batch(&d, task, &instances, ro.tokens, ro.logprobs, cfg)
}

/// Assemble a batch from rollout outputs (separated for reuse by the
/// grail pipeline, where rollouts arrive from remote miners).
pub fn build_batch(
    dims: &crate::runtime::manifest::Dims,
    task: &dyn Task,
    instances: &[Instance],
    tokens: Vec<i32>,
    old_logprobs: Vec<f32>,
    cfg: GrpoConfig,
) -> Result<Batch> {
    let (b, t, g) = (dims.batch, dims.seq, dims.gen_len);
    anyhow::ensure!(tokens.len() == b * t, "tokens shape");
    anyhow::ensure!(old_logprobs.len() == b * g, "logprobs shape");
    anyhow::ensure!(instances.len() == b, "instances");
    let mut rewards = Vec::with_capacity(b);
    let mut mask = Vec::with_capacity(b * g);
    let mut correct = 0usize;
    for row in 0..b {
        let completion = &tokens[row * t + dims.prompt_len..(row + 1) * t];
        let r = task.reward(&instances[row], completion);
        if r.correct >= 1.0 {
            correct += 1;
        }
        rewards.push(r.total);
        mask.extend(completion_mask(completion));
    }
    let advantages = group_advantages(&rewards, cfg.group, cfg.sigma_floor);
    let mean_reward = rewards.iter().sum::<f64>() / b as f64;
    Ok(Batch {
        tokens,
        old_logprobs,
        advantages,
        mask,
        rewards: rewards.clone(),
        mean_reward,
        correct_rate: correct as f64 / b as f64,
    })
}

/// pass@1: greedy rollouts on `n_eval` problems; fraction fully correct.
pub fn pass_at_1(
    rt: &ModelRuntime,
    flat: &[f32],
    task: &dyn Task,
    n_eval: usize,
    rng: &mut Rng,
) -> Result<f64> {
    let d = rt.manifest.dims.clone();
    let mut correct = 0usize;
    let mut total = 0usize;
    while total < n_eval {
        // fill a batch with distinct problems (group=1 semantics)
        let mut prompts = Vec::with_capacity(d.batch * d.prompt_len);
        let mut instances = Vec::with_capacity(d.batch);
        for _ in 0..d.batch {
            let (p, inst) = task.sample(d.prompt_len, rng);
            prompts.extend_from_slice(&p);
            instances.push(inst);
        }
        let ro = rt.rollout(flat, &prompts, [7, 7], 0.0)?;
        for row in 0..d.batch {
            if total >= n_eval {
                break;
            }
            let completion = &ro.tokens[row * d.seq + d.prompt_len..(row + 1) * d.seq];
            if task.reward(&instances[row], completion).correct >= 1.0 {
                correct += 1;
            }
            total += 1;
        }
    }
    Ok(correct as f64 / total as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rl::tasks::MathTask;
    use crate::rl::vocab::*;

    #[test]
    fn advantages_are_group_normalized() {
        let rewards = vec![1.0, 0.0, 1.0, 0.0, /* group 2 */ 0.5, 0.5, 0.5, 0.5];
        let adv = group_advantages(&rewards, 4, 1e-4);
        // group 1: mean 0.5, std 0.5 → ±1
        assert!((adv[0] - 1.0).abs() < 1e-6);
        assert!((adv[1] + 1.0).abs() < 1e-6);
        // group 2: constant rewards → 0 (sigma floored)
        assert_eq!(&adv[4..8], &[0.0, 0.0, 0.0, 0.0]);
        // zero-sum within each group
        assert!(adv[..4].iter().sum::<f32>().abs() < 1e-5);
    }

    #[test]
    fn mask_stops_after_eos() {
        assert_eq!(
            completion_mask(&[THINK, digit(1), EOS, PAD, PAD]),
            vec![1.0, 1.0, 1.0, 0.0, 0.0]
        );
        assert_eq!(completion_mask(&[digit(1); 4]), vec![1.0; 4]);
    }

    #[test]
    fn sample_prompts_repeats_per_group() {
        let task = MathTask::default();
        let mut rng = Rng::new(3);
        let (prompts, instances) = sample_prompts(&task, 8, 16, 4, &mut rng);
        assert_eq!(prompts.len(), 8 * 16);
        assert_eq!(instances.len(), 8);
        // rows 0..4 identical, different from rows 4..8 (w.h.p.)
        assert_eq!(&prompts[0..16], &prompts[16..32]);
        let g1: Vec<i32> = prompts[0..16].to_vec();
        let g2: Vec<i32> = prompts[4 * 16..5 * 16].to_vec();
        assert_ne!(g1, g2);
    }
}
