//! Stack-machine substrate for the code-generation task (the MBPP
//! stand-in, DESIGN.md §2): a tiny deterministic stack VM whose
//! programs the model emits token-by-token, verified by unit tests
//! exactly like MBPP's pass-rate reward (paper Eq. 22).

use super::vocab::*;

/// VM execution errors — these make a program fail a test, not panic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum VmError {
    StackUnderflow,
    NoEnd,
    BadToken(i32),
    EmptyStack,
    StepLimit,
}

/// Execute `program` (token ids) on `input`. The IN op pushes the
/// input; the result is the stack top at END. Arithmetic is wrapping
/// (the verifier only compares exact values).
pub fn run(program: &[i32], input: i64) -> Result<i64, VmError> {
    let mut stack: Vec<i64> = Vec::with_capacity(16);
    let mut steps = 0usize;
    for &tok in program {
        steps += 1;
        if steps > 256 {
            return Err(VmError::StepLimit);
        }
        match tok {
            t if (PUSH0..PUSH0 + 10).contains(&t) => stack.push((t - PUSH0) as i64),
            OP_IN => stack.push(input),
            OP_ADD | OP_SUB | OP_MUL => {
                let b = stack.pop().ok_or(VmError::StackUnderflow)?;
                let a = stack.pop().ok_or(VmError::StackUnderflow)?;
                stack.push(match tok {
                    OP_ADD => a.wrapping_add(b),
                    OP_SUB => a.wrapping_sub(b),
                    _ => a.wrapping_mul(b),
                });
            }
            OP_DUP => {
                let a = *stack.last().ok_or(VmError::StackUnderflow)?;
                stack.push(a);
            }
            OP_SWAP => {
                let n = stack.len();
                if n < 2 {
                    return Err(VmError::StackUnderflow);
                }
                stack.swap(n - 1, n - 2);
            }
            OP_END => return stack.last().copied().ok_or(VmError::EmptyStack),
            PAD | EOS => break, // treat trailing padding as missing END
            other => return Err(VmError::BadToken(other)),
        }
    }
    Err(VmError::NoEnd)
}

/// Syntax check: all tokens are VM ops and an END appears.
pub fn is_syntactically_valid(program: &[i32]) -> bool {
    let mut saw_end = false;
    for &tok in program {
        match tok {
            t if (PUSH0..PUSH0 + 10).contains(&t) => {}
            OP_IN | OP_ADD | OP_SUB | OP_MUL | OP_DUP | OP_SWAP => {}
            OP_END => {
                saw_end = true;
                break;
            }
            PAD | EOS => break,
            _ => return false,
        }
    }
    saw_end
}

/// Fraction of unit tests a program passes (the C_pass of Eq. 22).
pub fn pass_rate(program: &[i32], tests: &[(i64, i64)]) -> f64 {
    if tests.is_empty() {
        return 0.0;
    }
    let passed = tests
        .iter()
        .filter(|(input, expect)| run(program, *input) == Ok(*expect))
        .count();
    passed as f64 / tests.len() as f64
}

/// Reference solutions used to generate test cases (the "ground truth
/// programs" of the synthetic benchmark). Index = difficulty tier.
pub fn reference_programs() -> Vec<(&'static str, Vec<i32>)> {
    vec![
        ("identity", vec![OP_IN, OP_END]),
        ("square", vec![OP_IN, OP_DUP, OP_MUL, OP_END]),
        ("double", vec![OP_IN, OP_DUP, OP_ADD, OP_END]),
        ("add3", vec![OP_IN, PUSH0 + 3, OP_ADD, OP_END]),
        ("sub1", vec![OP_IN, PUSH0 + 1, OP_SUB, OP_END]),
        ("times5", vec![OP_IN, PUSH0 + 5, OP_MUL, OP_END]),
        ("x2plus1", vec![OP_IN, OP_DUP, OP_MUL, PUSH0 + 1, OP_ADD, OP_END]),
        ("const7", vec![PUSH0 + 7, OP_END]),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_programs_behave() {
        let progs = reference_programs();
        let get = |name: &str| {
            progs.iter().find(|(n, _)| *n == name).map(|(_, p)| p.clone()).unwrap()
        };
        assert_eq!(run(&get("identity"), 42), Ok(42));
        assert_eq!(run(&get("square"), 7), Ok(49));
        assert_eq!(run(&get("double"), -3), Ok(-6));
        assert_eq!(run(&get("add3"), 10), Ok(13));
        assert_eq!(run(&get("x2plus1"), 4), Ok(17));
        assert_eq!(run(&get("const7"), 999), Ok(7));
    }

    #[test]
    fn errors_not_panics() {
        assert_eq!(run(&[OP_ADD, OP_END], 1), Err(VmError::StackUnderflow));
        assert_eq!(run(&[OP_IN], 1), Err(VmError::NoEnd));
        assert_eq!(run(&[OP_END], 1), Err(VmError::EmptyStack));
        assert_eq!(run(&[EQ, OP_END], 1), Err(VmError::BadToken(EQ)));
        assert_eq!(run(&[], 5), Err(VmError::NoEnd));
    }

    #[test]
    fn syntax_checker() {
        assert!(is_syntactically_valid(&[OP_IN, OP_DUP, OP_MUL, OP_END]));
        assert!(is_syntactically_valid(&[OP_IN, OP_END, PAD, PAD]));
        assert!(!is_syntactically_valid(&[OP_IN, OP_DUP])); // no END
        assert!(!is_syntactically_valid(&[EQ, OP_END])); // non-VM token
        assert!(!is_syntactically_valid(&[OP_IN, EOS, OP_END])); // EOS cuts
    }

    #[test]
    fn pass_rate_counts_fractions() {
        let square = vec![OP_IN, OP_DUP, OP_MUL, OP_END];
        let tests = vec![(2, 4), (3, 9), (4, 17)]; // last one is wrong
        assert!((pass_rate(&square, &tests) - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(pass_rate(&[], &tests), 0.0);
    }

    #[test]
    fn prop_vm_never_panics_on_random_programs() {
        crate::util::prop::check("svm total", 100, |g| {
            let len = g.rng.below(16) as usize;
            let prog: Vec<i32> =
                (0..len).map(|_| g.rng.below(VOCAB as u64) as i32).collect();
            let _ = run(&prog, g.rng.range_i64(-100, 100));
            let _ = is_syntactically_valid(&prog);
        });
    }
}
