//! PULSESync: lossless trainer→inference weight synchronization
//! (paper §4.2 + §J).
//!
//! The trainer publishes, per optimizer step, a sparse **value patch**
//! (changed BF16 positions + their new bit patterns) and, every `k`
//! steps, a full **anchor** checkpoint. Inference workers follow the
//! delta stream (fast path: one patch per step) and fall back to
//! anchor + patch-chain on cold start, missed steps, or hash mismatch
//! (slow path, Alg. 5). Reconstruction is a memory overwrite with no
//! floating-point arithmetic, so chained patches stay bit-identical
//! (Prop. H.1).
//!
//! # One protocol, many fabrics
//!
//! [`Publisher`] and [`Consumer`] are generic over
//! [`crate::net::transport::SyncTransport`]: the same state machines
//! run over the S3-like object store ([`ObjectStoreTransport`], the
//! default — `Publisher::new(store, ...)` / `Consumer::new(store, ...)`
//! construct it), the TCP relay
//! ([`crate::net::transport::RelayTransport`]), the zero-I/O in-proc
//! backend ([`crate::net::transport::InProcTransport`]), or any of
//! those wrapped in deterministic fault injection
//! ([`crate::net::transport::FaultInjectingTransport`]). The protocol
//! (frames first, then a committing marker; integrity carried in the
//! frames, not the fabric) is the transport contract — see
//! [`crate::net::transport`] for it and for how to add a backend.
//!
//! # Verification cost model (§J.4, made O(nnz))
//!
//! Integrity is checked against a chunked hash tree
//! ([`crate::sparse::hashtree`]) instead of a scalar SHA-256 of the
//! whole buffer. Both sides keep the tree alongside their weights, so
//! per step:
//!
//! * the publisher's diff+gather is one fused word-skipping scan and its
//!   root update rehashes only the chunks the patch touches —
//!   O(nnz · chunk_elems) hashing instead of O(total_params);
//! * the consumer's [`crate::sparse::hashtree::HashTree::apply_and_rehash`]
//!   fuses the patch apply with the chunk rehash in one pass and
//!   compares the resulting root to the one in the patch's v2 container
//!   header (chunk size + root; see [`crate::sparse::container`]).
//!
//! Only the slow path still hashes the full buffer (building the tree
//! from a downloaded anchor — a parallel chunked build). Legacy v1
//! containers and plain-hex anchor markers verify via the scalar hash,
//! so stores written before the hash tree still synchronize.
//!
//! # Sharded pipelined fan-out
//!
//! With `Publisher::shard_count > 1` (or a [`ShardedEncoder`] driven
//! directly), each step is split into S contiguous element ranges
//! aligned to hash-tree chunk boundaries
//! ([`crate::sparse::hashtree::shard_ranges`]) — or, with
//! [`Publisher::with_shard_balancing`], into equal-nnz chunk-aligned
//! ranges cut along the measured per-chunk update profile
//! ([`crate::sparse::hashtree::balanced_shard_ranges`]), so a skewed
//! update stream no longer serializes behind its hottest shard. Per
//! shard, the fused diff+gather, the container encode+compress, and the
//! frame publish all run on the [`crate::util::pool`] worker pool, so
//! encode latency of one shard hides behind the upload of another.
//! Each shard travels as its own v3 container frame carrying
//! `(shard_index, shard_count, elem_offset, elem_len)`, its **subtree
//! root** over exactly its element range, and the step's global root
//! ([`crate::sparse::container`]).
//!
//! Store layout for a sharded step `t` (other fabrics carry the same
//! frames and marker strings — only the addressing differs):
//!
//! ```text
//!   delta_000000t.s000.bin … delta_000000t.s00{S-1}.bin   (shard frames)
//!   delta_ready_t = "v3:<S>:<global_root_hex>"            (commit marker)
//! ```
//!
//! The consumer fetches and decodes shard frames on the pool, applies
//! them in parallel
//! ([`crate::sparse::hashtree::HashTree::apply_and_rehash_shards`]),
//! and verifies each shard's subtree root independently. A shard whose
//! fetch or decode fails, or whose subtree root mismatches, is restored
//! *exactly* (values + chunk digests) and **re-fetched alone** through
//! the transport's repair seam — `SyncStats::shard_refetches` — while
//! the other shards stay applied; only a second failure abandons the
//! step to the anchor slow path. The assembled step is then bound end
//! to end by comparing the tree root against the marker's global root,
//! so sharded apply is bit-identical to the unsharded path by
//! construction and by test (the transport conformance suite runs this
//! on every backend).

use crate::codec::Codec;
use crate::net::transport::{
    FrameId, MarkerId, ObjectStoreTransport, StepData, SyncTransport,
};
use crate::sparse::container::{self, EncodeOpts, Patch, Values};
use crate::sparse::hashtree::{self, HashTree, ShardPatchRef, DEFAULT_CHUNK_ELEMS};
use crate::sparse::{self, TensorShape};
use crate::storage::retention::Inventory;
use crate::storage::ObjectStore;
use crate::util::{pool, sha256_hex, u16_as_bytes};
use anyhow::{bail, Context, Result};

/// Re-exported so existing callers keep a stable path.
pub use crate::net::transport::MAX_SHARDS;

/// Anchor ready-marker payload: `v2:<chunk_elems>:<root_hex>` for
/// hash-tree verification. Legacy markers are the bare scalar SHA-256
/// hex of the raw BF16 bytes and still verify.
///
/// Either form may carry a publisher-generation prefix (`g<n>;`, see
/// [`crate::net::transport::split_generation`]): a [`Publisher`] that
/// resumed after a crash re-commits the anchor it recovered from and
/// publishes every subsequent marker under the next generation, so
/// consumers can tell a rewound lineage from a stale poll. Generation
/// 0 omits the prefix, keeping pre-recovery stores byte-identical.
fn anchor_marker(tree: &HashTree) -> String {
    format!("v2:{}:{}", tree.chunk_elems(), tree.root_hex())
}

fn parse_anchor_marker(s: &str) -> Option<(usize, &str)> {
    let rest = s.strip_prefix("v2:")?;
    let (chunk, root) = rest.split_once(':')?;
    let chunk: usize = chunk.parse().ok()?;
    // untrusted geometry: same wire minimum as the container header, so
    // a corrupted marker fails verification instead of exploding the
    // digest allocation
    if chunk < crate::sparse::hashtree::MIN_WIRE_CHUNK_ELEMS {
        return None;
    }
    Some((chunk, root))
}

/// Publisher-side statistics for one published step.
#[derive(Debug, Clone, Default)]
pub struct PublishStats {
    pub step: u64,
    pub nnz: usize,
    pub total: usize,
    pub patch_bytes: u64,
    pub anchor_bytes: u64,
    pub sparsity: f64,
    pub encode_secs: f64,
    /// Effective shards this step was published as (1 = unsharded).
    pub shard_count: usize,
    /// Per-shard container bytes (one entry per shard, index order).
    pub shard_bytes: Vec<u64>,
    /// Per-shard encode+compress seconds (wall, on the pool).
    pub shard_encode_secs: Vec<f64>,
}

/// One encoded shard frame of a step.
#[derive(Debug, Clone)]
pub struct ShardFrame {
    pub shard_index: u32,
    pub elem_offset: u64,
    pub elem_len: u64,
    pub nnz: usize,
    /// The container object (v2 for a single-shard step, v3 otherwise).
    pub bytes: Vec<u8>,
    pub encode_secs: f64,
}

/// A fully encoded step: one frame per shard. With `shard_count == 1`
/// the single frame is byte-identical to the classic unsharded v2
/// container.
#[derive(Debug, Clone)]
pub struct EncodedStep {
    pub step: u64,
    /// Global hash-tree root after this step applies.
    pub root: String,
    pub nnz: usize,
    pub frames: Vec<ShardFrame>,
}

/// Trainer-side sharded patch encoder: owns the previously published
/// BF16 view and its hash tree, and turns each new view into one
/// container frame per shard (per-shard diff+gather and
/// encode+compress run on the worker pool). [`Publisher`] drives it
/// against a [`SyncTransport`]; tests and benches can drive it
/// directly and ship the frames however they like.
pub struct ShardedEncoder {
    prev: Vec<u16>,
    prev_step: u64,
    tree: HashTree,
    /// Cut shard ranges along the measured per-chunk nnz profile
    /// (equal-nnz shards) instead of the static equal-element split.
    pub balance: bool,
}

impl ShardedEncoder {
    /// Start from the view published at `start_step` (builds the tree).
    pub fn new(initial: Vec<u16>, start_step: u64) -> ShardedEncoder {
        let tree = HashTree::build(&initial, DEFAULT_CHUNK_ELEMS);
        ShardedEncoder { prev: initial, prev_step: start_step, tree, balance: false }
    }

    pub fn current(&self) -> &[u16] {
        &self.prev
    }

    pub fn current_step(&self) -> u64 {
        self.prev_step
    }

    pub fn tree(&self) -> &HashTree {
        &self.tree
    }

    /// Encode step `step` (must be `current_step() + 1`) for view
    /// `new`. On success the encoder advances to `new`; on error it is
    /// left consistent at the previous step.
    pub fn encode_step(
        &mut self,
        step: u64,
        new: &[u16],
        layout: &[TensorShape],
        opts: EncodeOpts,
        shard_count: usize,
    ) -> Result<EncodedStep> {
        if new.len() != self.prev.len() {
            bail!("checkpoint size changed ({} -> {})", self.prev.len(), new.len());
        }
        if step != self.prev_step + 1 {
            bail!("publish steps must be consecutive ({} after {})", step, self.prev_step);
        }
        // cap at the wire limit consumers accept, or a marker could
        // advertise a shard count no consumer will ever apply
        let shard_count = shard_count.clamp(1, MAX_SHARDS as usize);
        let ce = self.tree.chunk_elems();
        let ranges = if self.balance && shard_count > 1 {
            let counts = sparse::count_diff_bf16_blocks(&self.prev, new, ce);
            hashtree::balanced_shard_ranges(&counts, ce, new.len(), shard_count)
        } else {
            hashtree::shard_ranges(new.len(), ce, shard_count)
        };
        // whichever split chose the cuts, shards must stay chunk-aligned
        // or subtree roots would not be derivable from shared per-chunk
        // state (and the consumer's partition validation would reject
        // the step)
        let mut expect_lo = 0usize;
        for r in &ranges {
            assert!(
                r.start == expect_lo
                    && r.start % ce == 0
                    && (r.end % ce == 0 || r.end == new.len()),
                "shard ranges must stay chunk-aligned"
            );
            expect_lo = r.end;
        }
        assert!(expect_lo == new.len() && ranges.len() <= shard_count);
        // phase 1: fused diff+gather. Unsharded keeps the globally
        // parallel scan; sharded runs one serial scan per shard on its
        // own pool worker (shard-level parallelism without nesting a
        // second thread fan-out inside each worker).
        let prev = &self.prev;
        let parts: Vec<(Vec<u64>, Vec<u16>)> = if ranges.len() == 1 {
            vec![sparse::diff_gather_bf16(prev, new)]
        } else {
            pool::par_map(ranges.clone(), |_, r| sparse::diff_gather_bf16_range(prev, new, r))
        };
        // phase 2: one incremental tree update over all touched chunks,
        // then read the global + per-shard roots
        let all_idx: Vec<u64> =
            parts.iter().flat_map(|(idx, _)| idx.iter().copied()).collect();
        let nnz = all_idx.len();
        self.tree.update(new, &all_idx);
        drop(all_idx);
        let root = self.tree.root_hex();
        let s_eff = ranges.len() as u32;
        let mut patches = Vec::with_capacity(parts.len());
        for (i, ((indices, values), r)) in parts.into_iter().zip(ranges.iter()).enumerate() {
            let mut p = Patch {
                step,
                base_step: self.prev_step,
                total_params: new.len() as u64,
                indices,
                values: Values::Bf16(values),
                result_hash: root.clone(),
                chunk_elems: self.tree.chunk_elems() as u64,
                ..Default::default()
            };
            p.elem_offset = r.start as u64;
            p.elem_len = (r.end - r.start) as u64;
            if s_eff > 1 {
                p.shard_index = i as u32;
                p.shard_count = s_eff;
                p.shard_root = self.tree.subtree_root_hex(r.start, r.end);
            }
            patches.push(p);
        }
        // phase 3: per-shard container encode+compress on the pool
        let encoded: Vec<Result<ShardFrame>> = pool::par_map(patches, |i, p| {
            let t = crate::util::Stopwatch::start();
            let bytes = container::encode(&p, layout, opts)?;
            Ok(ShardFrame {
                shard_index: i as u32,
                elem_offset: p.elem_offset,
                elem_len: p.elem_len,
                nnz: p.indices.len(),
                bytes,
                encode_secs: t.secs(),
            })
        });
        let mut frames = Vec::with_capacity(encoded.len());
        for fr in encoded {
            match fr {
                Ok(f) => frames.push(f),
                Err(e) => {
                    // the tree already reflects `new` but `prev` does
                    // not; rebuild from `prev` so an abandoned encode
                    // leaves the encoder consistent (error path only)
                    self.tree = HashTree::build(&self.prev, self.tree.chunk_elems());
                    return Err(e);
                }
            }
        }
        self.prev.copy_from_slice(new);
        self.prev_step = step;
        Ok(EncodedStep { step, root, nnz, frames })
    }
}

/// Trainer-side publisher (Alg. 5 `PublishCheckpoint`), generic over
/// the sync fabric. `Publisher::new(store, prefix, ...)` builds the
/// object-store instance; [`Publisher::over`] accepts any transport.
pub struct Publisher<T: SyncTransport = ObjectStoreTransport> {
    pub transport: T,
    pub layout: Vec<TensorShape>,
    pub opts: EncodeOpts,
    /// Anchor interval k (paper uses 50).
    pub anchor_interval: u64,
    /// Shards per published step (1 = classic single-frame publish;
    /// shard ranges align to hash-tree chunk boundaries).
    pub shard_count: usize,
    /// Publisher generation: 0 for a fresh lineage (markers stay
    /// untagged, wire-compatible with every earlier store), bumped by
    /// [`Publisher::resume_over`] after a crash so consumers detect
    /// the rewound lineage from the `g<n>;` marker prefix.
    pub generation: u64,
    /// Previous published view + hash tree, advanced per publish.
    enc: ShardedEncoder,
    /// Test hook: force the next delta upload to fail (§J.5 recovery).
    pub fail_next_delta: bool,
}

/// Read the newest anchor on `transport`: the recovery point for a
/// restarted publisher. Returns the anchor's weights, its step, and
/// the generation its ready marker carries (0 for untagged markers).
/// The anchor is verified against its marker before being trusted.
pub fn recover_anchor_state<T: SyncTransport>(transport: &T) -> Result<(Vec<u16>, u64, u64)> {
    let inv = transport.latest_ready()?;
    let step = inv
        .anchor_steps
        .last()
        .copied()
        .ok_or_else(|| anyhow::anyhow!("no anchor to resume from on {}", transport.name()))?;
    let (obj, marker) = transport
        .fetch_anchor(step)
        .with_context(|| format!("recovery anchor {}", step))?;
    if obj.len() < 20 || &obj[0..4] != b"PLSA" {
        bail!("bad anchor header");
    }
    let astep = u64::from_le_bytes(obj[4..12].try_into().unwrap());
    let n = u64::from_le_bytes(obj[12..20].try_into().unwrap()) as usize;
    if astep != step {
        bail!("anchor step mismatch");
    }
    let raw = Codec::Zstd1.decompress(&obj[20..], n * 2)?;
    let w = crate::util::bytes_to_u16(&raw);
    if w.len() != n {
        bail!("anchor length mismatch");
    }
    let (generation, body) = crate::net::transport::split_generation(&marker);
    if let Some((chunk_elems, root)) = parse_anchor_marker(body) {
        if HashTree::build(&w, chunk_elems).root_hex() != root {
            bail!("anchor hash mismatch at step {}", step);
        }
    } else if !body.is_empty() && body != sha256_hex(u16_as_bytes(&w)) {
        bail!("anchor hash mismatch at step {}", step);
    }
    Ok((w, step, generation))
}

impl Publisher<ObjectStoreTransport> {
    /// Create an object-store publisher and publish step 0 as the
    /// initial anchor (the pre-trait constructor, kept stable).
    pub fn new(
        store: ObjectStore,
        prefix: &str,
        layout: Vec<TensorShape>,
        initial: Vec<u16>,
        anchor_interval: u64,
    ) -> Result<Publisher<ObjectStoreTransport>> {
        Publisher::over(ObjectStoreTransport::new(store, prefix), layout, initial, anchor_interval)
    }
}

impl<T: SyncTransport> Publisher<T> {
    /// Create a publisher over any transport and publish step 0 as the
    /// initial anchor.
    pub fn over(
        transport: T,
        layout: Vec<TensorShape>,
        initial: Vec<u16>,
        anchor_interval: u64,
    ) -> Result<Publisher<T>> {
        let mut p = Publisher {
            transport,
            layout,
            opts: EncodeOpts::default(),
            anchor_interval: anchor_interval.max(1),
            shard_count: 1,
            generation: 0,
            enc: ShardedEncoder::new(initial, 0),
            fail_next_delta: false,
        };
        p.upload_anchor(0)?;
        Ok(p)
    }

    /// Continue an existing lineage after a crash: start from `weights`
    /// at `step`, publish as `generation`, and immediately re-commit
    /// the anchor under the new generation so consumers detect the
    /// bump. Steps published by the dead publisher past `step` are
    /// abandoned — the lineage rewinds to the anchor, exactly as §J.5
    /// rewinds a single failed delta.
    pub fn resume(
        transport: T,
        layout: Vec<TensorShape>,
        weights: Vec<u16>,
        step: u64,
        generation: u64,
        anchor_interval: u64,
    ) -> Result<Publisher<T>> {
        let mut p = Publisher {
            transport,
            layout,
            opts: EncodeOpts::default(),
            anchor_interval: anchor_interval.max(1),
            shard_count: 1,
            generation,
            enc: ShardedEncoder::new(weights, step),
            fail_next_delta: false,
        };
        p.upload_anchor(step)?;
        Ok(p)
    }

    /// Crash-recovery constructor: resume from the transport's own
    /// newest anchor as the next generation
    /// ([`recover_anchor_state`] + [`Publisher::resume`]).
    pub fn resume_over(
        transport: T,
        layout: Vec<TensorShape>,
        anchor_interval: u64,
    ) -> Result<Publisher<T>> {
        let (w, step, gen) = recover_anchor_state(&transport)?;
        Publisher::resume(transport, layout, w, step, gen + 1, anchor_interval)
    }

    /// Ready-marker text for this publisher's generation: untagged for
    /// generation 0, `g<n>;`-prefixed otherwise.
    fn marker_text(&self, body: &str) -> String {
        if self.generation == 0 {
            body.to_string()
        } else {
            format!("g{};{}", self.generation, body)
        }
    }

    /// Builder-style shard count override (clamped to [`MAX_SHARDS`]).
    pub fn with_shards(mut self, shards: usize) -> Publisher<T> {
        self.shard_count = shards.clamp(1, MAX_SHARDS as usize);
        self
    }

    /// Builder-style toggle for the equal-nnz load-balanced shard
    /// split (see [`crate::sparse::hashtree::balanced_shard_ranges`]).
    pub fn with_shard_balancing(mut self, on: bool) -> Publisher<T> {
        self.enc.balance = on;
        self
    }

    pub fn current_step(&self) -> u64 {
        self.enc.current_step()
    }

    pub fn current_weights(&self) -> &[u16] {
        self.enc.current()
    }

    pub fn tree(&self) -> &HashTree {
        self.enc.tree()
    }

    fn upload_anchor(&mut self, step: u64) -> Result<u64> {
        // Anchor = zstd-1-compressed raw BF16 bytes + 16-byte header.
        let raw = u16_as_bytes(self.enc.current());
        let comp = Codec::Zstd1.compress(raw)?;
        let mut obj = Vec::with_capacity(comp.len() + 16);
        obj.extend_from_slice(b"PLSA");
        obj.extend_from_slice(&step.to_le_bytes());
        obj.extend_from_slice(&(self.enc.current().len() as u64).to_le_bytes());
        obj.extend_from_slice(&comp);
        self.transport.publish_frame(FrameId::Anchor { step }, &obj)?;
        // anchor ready marker carries the hash-tree geometry + root
        // (plus the generation tag for resumed lineages)
        let marker = self.marker_text(&anchor_marker(self.enc.tree()));
        self.transport.publish_marker(MarkerId::Anchor(step), &marker)?;
        Ok(obj.len() as u64)
    }

    /// Publish optimizer step `step` whose BF16 view is `new`.
    ///
    /// Encodes per shard on the worker pool, publishes the shard frames
    /// (also on the pool, so uploads overlap), then commits the
    /// ready marker; the anchor follows if `step % k == 0` (paper §J.1
    /// "concurrent uploads"). If the delta upload fails, falls back to
    /// publishing a full anchor for this step (§J.5).
    pub fn publish(&mut self, step: u64, new: &[u16]) -> Result<PublishStats> {
        let t = crate::util::Stopwatch::start();
        let encoded =
            self.enc.encode_step(step, new, &self.layout, self.opts, self.shard_count)?;
        let mut stats = PublishStats {
            step,
            nnz: encoded.nnz,
            total: new.len(),
            patch_bytes: encoded.frames.iter().map(|f| f.bytes.len() as u64).sum(),
            anchor_bytes: 0,
            sparsity: sparse::sparsity(encoded.nnz, new.len()),
            encode_secs: 0.0,
            shard_count: encoded.frames.len(),
            shard_bytes: encoded.frames.iter().map(|f| f.bytes.len() as u64).collect(),
            shard_encode_secs: encoded.frames.iter().map(|f| f.encode_secs).collect(),
        };

        let delta_failed = std::mem::take(&mut self.fail_next_delta);
        if delta_failed {
            // §J.5: delta upload failure → publish a full anchor so the
            // chain stays recoverable, and skip the delta marker.
            stats.anchor_bytes = self.upload_anchor(step)?;
            stats.encode_secs = t.secs();
            return Ok(stats);
        }
        if encoded.frames.len() == 1 {
            self.transport
                .publish_frame(FrameId::Delta { step }, &encoded.frames[0].bytes)?;
            self.transport
                .publish_marker(MarkerId::Delta(step), &self.marker_text(&encoded.root))?;
            crate::obs::span(
                crate::obs::Stage::Publish,
                self.generation,
                step,
                0,
                encoded.frames[0].bytes.len() as u64,
            );
        } else {
            // pipelined fan-out: each shard frame publishes on its own
            // pool worker, overlapping fabric latency across shards;
            // the marker commits only after every frame landed
            let tr = &self.transport;
            let uploads: Vec<(u32, &Vec<u8>)> =
                encoded.frames.iter().map(|f| (f.shard_index, &f.bytes)).collect();
            let results: Vec<Result<()>> = pool::par_map(uploads, |_, (shard, bytes)| {
                tr.publish_frame(FrameId::Shard { step, shard }, bytes)
            });
            for r in results {
                r?;
            }
            let marker = crate::net::transport::sharded_marker(
                encoded.frames.len() as u32,
                &encoded.root,
            );
            self.transport
                .publish_marker(MarkerId::Delta(step), &self.marker_text(&marker))?;
            // one span per committed shard frame: the marker is the
            // step's commit point, so the spans carry its timestamp
            for f in &encoded.frames {
                crate::obs::span(
                    crate::obs::Stage::Publish,
                    self.generation,
                    step,
                    f.shard_index,
                    f.bytes.len() as u64,
                );
            }
        }
        if step % self.anchor_interval == 0 {
            stats.anchor_bytes = self.upload_anchor(step)?;
        }
        stats.encode_secs = t.secs();
        Ok(stats)
    }
}

/// Consumer-side statistics for one synchronize() call.
#[derive(Debug, Clone, Default)]
pub struct SyncStats {
    // pallas-lint: allow(counter-csv-drift): per-call step bracket, meaningless summed across calls
    pub from_step: u64,
    // pallas-lint: allow(counter-csv-drift): per-call step bracket, meaningless summed across calls
    pub to_step: u64,
    pub path: SyncPath,
    /// Which transport backend served this call.
    pub transport: &'static str,
    /// Total bytes transferred during this call, including any fast-
    /// path attempt that was abandoned for the slow path.
    pub bytes_downloaded: u64,
    /// Sparse delta patches applied on the chain that produced the
    /// final weights (anchor restarts are counted in
    /// `anchors_restored`, not here; an abandoned fast-path attempt
    /// counts toward neither).
    pub patches_applied: usize,
    /// Full anchors downloaded and restored from on that chain: the
    /// slow-path base anchor plus any §J.5 anchor that replaced a
    /// failed delta upload.
    pub anchors_restored: usize,
    /// Shard frames re-fetched after a fetch failure, a decode failure
    /// or a subtree-root mismatch (the other shards of the step stay
    /// applied).
    pub shard_refetches: usize,
    /// Repair fetches the transport reported as unserviceable (relay
    /// NACK answered with NACK_MISS: the slot is evicted along the
    /// whole path to the publisher). Each one abandons its step to the
    /// anchor slow path instead of waiting out the NACK timeout.
    /// Survives the fast-path → slow-path fallback, like
    /// `bytes_downloaded`.
    pub nacks_unserviceable: usize,
    /// Repair NACKs re-sent after a backoff boundary passed with the
    /// retransmit still missing (snapshot of
    /// `TransportCounters::retries`, cumulative like `reparents`).
    pub retries: u64,
    /// Repair fetches whose whole [`crate::util::retry::RetryPolicy`]
    /// budget drained without a retransmit (cumulative snapshot of
    /// `TransportCounters::gave_up`).
    pub gave_up: u64,
    /// Duplicate repair NACKs the transport suppressed because the
    /// same `(step, shard)` already had one in flight (cumulative
    /// snapshot of `TransportCounters::nack_suppressed`).
    pub nack_suppressed: u64,
    /// Publisher generation this consumer last anchored against (0
    /// until a generation-tagged anchor is seen; bumps when a
    /// restarted publisher's re-anchor is adopted).
    pub generation: u64,
    /// Cumulative upstream re-parents the transport has performed so
    /// far (control-plane fabrics; 0 on statically-wired backends).
    /// Snapshot of `TransportCounters::reparents` at the end of the
    /// call, so a jump between two calls brackets a failover.
    pub reparents: u64,
    /// Topology epoch the transport last accepted (control plane;
    /// 0 on statically-wired backends, which never replan).
    pub epoch: u64,
    /// Store plane: GETs answered from a cache without an origin body
    /// read (cumulative snapshot of `TransportCounters::cache_hits`).
    pub cache_hits: u64,
    /// Store plane: GETs that went past every cache (cumulative
    /// snapshot of `TransportCounters::cache_misses`).
    pub cache_misses: u64,
    /// Store plane: object bodies pulled from the origin — the egress
    /// the caching tree bounds (cumulative snapshot of
    /// `TransportCounters::origin_fetches`).
    pub origin_fetches: u64,
    /// Store plane: conditional GETs answered NOT_MODIFIED because the
    /// content-address ETag still matched (cumulative snapshot of
    /// `TransportCounters::conditional_not_modified`).
    pub conditional_not_modified: u64,
    pub verified: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SyncPath {
    #[default]
    UpToDate,
    Fast,
    Chain,
    Slow,
}

/// Inference-worker consumer (Alg. 5 `Synchronize`), generic over the
/// sync fabric. `Consumer::new(store, prefix, layout)` builds the
/// object-store instance; [`Consumer::over`] accepts any transport.
pub struct Consumer<T: SyncTransport = ObjectStoreTransport> {
    pub transport: T,
    pub layout: Vec<TensorShape>,
    /// Local BF16 weights (None until first slow-path sync).
    pub weights: Option<Vec<u16>>,
    pub step: u64,
    /// Publisher generation of the last anchor adopted (0 until a
    /// `g<n>;`-tagged marker is seen). A bump means the publisher
    /// restarted and rewound; the consumer re-anchors on the new
    /// lineage instead of chaining across it.
    pub generation: u64,
    /// Hash tree mirroring `weights`, reused across synchronize() calls
    /// so the fast path verifies in O(nnz · chunk). None until built
    /// from an anchor, or after a legacy v1 patch made it stale.
    tree: Option<HashTree>,
    /// Inventory snapshot taken by [`Consumer::latest_ready`], consumed
    /// by the next [`Consumer::synchronize`] so the poll-then-sync
    /// pattern costs one backend scan, not two.
    cached_inv: Option<Inventory>,
}

/// Latest step with a delta-ready (or anchor-ready) marker in `inv` —
/// the "head" a consumer converges to. Public so the scale simulator
/// (`crate::sim`) applies the same convergence rule to modeled leaves.
pub fn latest_of(inv: &Inventory) -> Option<u64> {
    inv.delta_steps
        .last()
        .copied()
        .into_iter()
        .chain(inv.anchor_steps.last().copied())
        .max()
}

/// Slow-path anchor choice: the nearest anchor at or below `target`.
/// Shared by [`Consumer::synchronize`] and the simulator's modeled
/// catch-up, so simulated slow paths pick the same restart point the
/// real consumer would.
pub fn slow_path_anchor(inv: &Inventory, target: u64) -> Option<u64> {
    inv.anchor_steps.iter().filter(|&&a| a <= target).next_back().copied()
}

impl Consumer<ObjectStoreTransport> {
    /// Object-store consumer (the pre-trait constructor, kept stable).
    pub fn new(store: ObjectStore, prefix: &str, layout: Vec<TensorShape>) -> Consumer {
        Consumer::over(ObjectStoreTransport::new(store, prefix), layout)
    }
}

impl<T: SyncTransport> Consumer<T> {
    /// Consumer over any transport.
    pub fn over(transport: T, layout: Vec<TensorShape>) -> Consumer<T> {
        Consumer {
            transport,
            layout,
            weights: None,
            step: 0,
            generation: 0,
            tree: None,
            cached_inv: None,
        }
    }

    /// Root of the hash tree mirroring the local weights (None before
    /// the first sync or after a legacy v1 chain dropped the tree).
    pub fn tree_root(&self) -> Option<String> {
        self.tree.as_ref().map(|t| t.root_hex())
    }

    /// Latest step with a delta-ready (or anchor-ready) marker. The
    /// snapshot is cached and reused by the next [`Self::synchronize`]
    /// call, collapsing the poll-then-sync pattern to one scan.
    pub fn latest_ready(&mut self) -> Result<Option<u64>> {
        let inv = self.transport.latest_ready()?;
        let head = latest_of(&inv);
        self.cached_inv = Some(inv);
        Ok(head)
    }

    /// Synchronize to the newest published checkpoint. Implements the
    /// fast path (single patch), chain path (several patches), and slow
    /// path (anchor + chain); falls back to the slow path on any
    /// verification failure (§J.5 self-healing).
    pub fn synchronize(&mut self) -> Result<SyncStats> {
        let t = crate::util::Stopwatch::start();
        let mut stats = self.synchronize_inner()?;
        // stamp the transport's topology bookkeeping (control-plane
        // fabrics; zero on static backends) so per-sync rows can show
        // failover cost next to the apply/refetch tallies
        let counters = self.transport.counters();
        stats.reparents = counters.reparents;
        stats.epoch = counters.epoch;
        stats.retries = counters.retries;
        stats.gave_up = counters.gave_up;
        stats.nack_suppressed = counters.nack_suppressed;
        stats.cache_hits = counters.cache_hits;
        stats.cache_misses = counters.cache_misses;
        stats.origin_fetches = counters.origin_fetches;
        stats.conditional_not_modified = counters.conditional_not_modified;
        crate::obs::hist_secs(crate::obs::HistKind::E2eStep, t.secs());
        Ok(stats)
    }

    fn synchronize_inner(&mut self) -> Result<SyncStats> {
        // one inventory scan serves the head lookup and the slow-path
        // anchor choice — reusing the snapshot a preceding
        // latest_ready() already paid for. A cached snapshot that saw
        // no checkpoints is discarded and rescanned: it may predate the
        // first publish, and failing on it would turn a stale poll into
        // a hard error (a stale-but-nonempty snapshot is fine — we sync
        // to its head and the next poll catches up).
        let inv = match self.cached_inv.take() {
            Some(inv) if latest_of(&inv).is_some() => inv,
            _ => self.transport.latest_ready()?,
        };
        let latest = match latest_of(&inv) {
            Some(s) => s,
            None => bail!("no checkpoints published on {}", self.transport.name()),
        };
        let mut stats = SyncStats {
            from_step: self.step,
            to_step: latest,
            transport: self.transport.name(),
            generation: self.generation,
            ..Default::default()
        };
        if self.weights.is_some() && latest == self.step {
            stats.path = SyncPath::UpToDate;
            stats.verified = true;
            return Ok(stats);
        }
        if let Some(w) = self.weights.clone() {
            if latest > self.step {
                // try fast/chain path: apply deltas step+1 ..= latest
                let tree = self.tree.take();
                match self.apply_chain(w, tree, self.step, latest, &mut stats) {
                    Ok((weights, tree)) => {
                        self.weights = Some(weights);
                        self.tree = tree;
                        self.step = latest;
                        self.generation = self.generation.max(stats.generation);
                        stats.path = if latest == stats.from_step + 1 {
                            SyncPath::Fast
                        } else {
                            SyncPath::Chain
                        };
                        stats.verified = true;
                        return Ok(stats);
                    }
                    Err(_) => {
                        // fall through to slow path; drop the abandoned
                        // attempt's apply counters (the slow path rebuilds
                        // from an anchor) but keep bytes_downloaded — those
                        // bytes really were transferred
                        stats.patches_applied = 0;
                        stats.anchors_restored = 0;
                    }
                }
            }
            // latest < self.step: the head moved backwards — a restarted
            // publisher re-anchored below us and rewound the lineage.
            // Skip the (vacuous) chain attempt and re-anchor on the new
            // generation via the slow path.
        }
        // slow path: nearest anchor ≤ latest, then chain
        let t_slow = crate::util::Stopwatch::start();
        let anchor = slow_path_anchor(&inv, latest)
            .ok_or_else(|| anyhow::anyhow!("no anchor available for slow path"))?;
        let (w, tree, bytes, agen) = self.download_anchor(anchor)?;
        stats.bytes_downloaded += bytes;
        stats.anchors_restored += 1;
        stats.generation = stats.generation.max(agen);
        let (weights, tree) = self.apply_chain(w, tree, anchor, latest, &mut stats)?;
        self.weights = Some(weights);
        self.tree = tree;
        self.step = latest;
        self.generation = self.generation.max(stats.generation);
        stats.path = SyncPath::Slow;
        stats.verified = true;
        crate::obs::hist_secs(crate::obs::HistKind::CatchUp, t_slow.secs());
        crate::obs::span(
            crate::obs::Stage::CatchUp,
            stats.generation,
            latest,
            0,
            stats.bytes_downloaded,
        );
        Ok(stats)
    }

    /// Download + verify an anchor, returning its hash tree when the
    /// ready marker carries v2 geometry (legacy scalar markers verify
    /// via the full-buffer hash and return no tree), plus the
    /// publisher generation its marker carries (0 when untagged).
    fn download_anchor(&self, step: u64) -> Result<(Vec<u16>, Option<HashTree>, u64, u64)> {
        let (obj, expect) = self
            .transport
            .fetch_anchor(step)
            .with_context(|| format!("anchor {}", step))?;
        if obj.len() < 20 || &obj[0..4] != b"PLSA" {
            bail!("bad anchor header");
        }
        let astep = u64::from_le_bytes(obj[4..12].try_into().unwrap());
        let n = u64::from_le_bytes(obj[12..20].try_into().unwrap()) as usize;
        if astep != step {
            bail!("anchor step mismatch");
        }
        let raw = Codec::Zstd1.decompress(&obj[20..], n * 2)?;
        let w = crate::util::bytes_to_u16(&raw);
        if w.len() != n {
            bail!("anchor length mismatch");
        }
        // verify against the ready marker (and keep the tree it implies)
        let (agen, expect) = crate::net::transport::split_generation(&expect);
        let tree = if let Some((chunk_elems, root)) = parse_anchor_marker(expect) {
            let t = HashTree::build(&w, chunk_elems);
            if t.root_hex() != root {
                bail!("anchor hash mismatch at step {}", step);
            }
            Some(t)
        } else {
            if !expect.is_empty() && expect != sha256_hex(u16_as_bytes(&w)) {
                bail!("anchor hash mismatch at step {}", step);
            }
            None
        };
        Ok((w, tree, obj.len() as u64, agen))
    }

    /// Apply deltas `(from, to]` onto `w`, verifying each patch's
    /// embedded hash-tree root (Alg. 5 lines 25–29) with a fused
    /// apply+rehash over only the touched chunks. Steps whose delta is
    /// missing but which have their own anchor are restarted from that
    /// anchor (delta-upload-failure recovery). Returns the weights and
    /// the tree kept current with them.
    fn apply_chain(
        &self,
        mut w: Vec<u16>,
        mut tree: Option<HashTree>,
        from: u64,
        to: u64,
        stats: &mut SyncStats,
    ) -> Result<(Vec<u16>, Option<HashTree>)> {
        for t in from + 1..=to {
            let step_data = match self.transport.fetch_step(t)? {
                Some(d) => d,
                None => {
                    // §J.5: a failed delta upload was replaced by an
                    // anchor.
                    let (aw, atree, bytes, agen) = self.download_anchor(t)?;
                    w = aw;
                    tree = atree;
                    stats.bytes_downloaded += bytes;
                    stats.anchors_restored += 1;
                    stats.generation = stats.generation.max(agen);
                    continue;
                }
            };
            let obj = match step_data {
                StepData::Sharded { shard_count, root } => {
                    self.apply_sharded(t, shard_count, &root, &mut w, &mut tree, stats)?;
                    stats.patches_applied += 1;
                    continue;
                }
                StepData::Whole(obj) => obj,
            };
            stats.bytes_downloaded += obj.len() as u64;
            let patch = container::decode(&obj, &self.layout)?;
            if patch.step != t {
                bail!("patch step mismatch: got {}, want {}", patch.step, t);
            }
            let values = match &patch.values {
                Values::Bf16(v) => v,
                _ => bail!("weight patch carries non-bf16 values"),
            };
            // a corrupted-but-decodable index stream must degrade into
            // this chain erroring (→ slow path), never an out-of-bounds
            // panic inside the apply
            let mut prev_idx: Option<u64> = None;
            for &i in &patch.indices {
                if i as usize >= w.len() {
                    bail!("patch {} index {} out of bounds ({})", t, i, w.len());
                }
                if prev_idx.is_some_and(|p| i <= p) {
                    bail!("patch {} index stream not strictly sorted", t);
                }
                prev_idx = Some(i);
            }
            if patch.chunk_elems > 0 {
                // v2: fused apply + chunk rehash, O(nnz · chunk) verify.
                // Rebuild the tree only when absent or its geometry
                // disagrees with the patch header.
                let ce = patch.chunk_elems as usize;
                let mut ht = match tree.take() {
                    Some(ht) if ht.chunk_elems() == ce && ht.total_elems() == w.len() => ht,
                    _ => HashTree::build(&w, ce),
                };
                ht.apply_and_rehash(&mut w, &patch.indices, values);
                if ht.root_hex() != patch.result_hash {
                    bail!("hash mismatch after applying patch {}", t);
                }
                tree = Some(ht);
            } else {
                // legacy v1: scalar full-buffer hash
                sparse::apply_u16(&mut w, &patch.indices, values);
                if sha256_hex(u16_as_bytes(&w)) != patch.result_hash {
                    bail!("hash mismatch after applying patch {}", t);
                }
                tree = None;
            }
            stats.patches_applied += 1;
            crate::obs::span(crate::obs::Stage::Apply, stats.generation, t, 0, obj.len() as u64);
        }
        Ok((w, tree))
    }

    /// One counted repair fetch through the transport's repair seam.
    /// A repair the transport reports as unserviceable (the relay path
    /// has evicted the slot) is tallied separately — the error still
    /// propagates, abandoning the step to the anchor slow path.
    fn refetch_shard(&self, step: u64, shard: u32, stats: &mut SyncStats) -> Result<Vec<u8>> {
        match self.transport.fetch_shard(step, shard) {
            Ok(obj) => {
                stats.bytes_downloaded += obj.len() as u64;
                Ok(obj)
            }
            Err(e) => {
                if crate::net::transport::is_unserviceable(&e) {
                    stats.nacks_unserviceable += 1;
                }
                Err(e).with_context(|| format!("shard {} of step {}", shard, step))
            }
        }
    }

    /// Apply one sharded step: fetch + decode all shard frames (decode
    /// on the pool), apply them in parallel with per-shard subtree
    /// verification, re-fetch any shard that fails — at fetch, decode,
    /// or verify time — exactly once, then bind the assembled step to
    /// the marker's global root. Any unrecoverable failure propagates,
    /// sending the caller to the anchor slow path.
    fn apply_sharded(
        &self,
        step: u64,
        shard_count: u32,
        expect_root: &str,
        w: &mut Vec<u16>,
        tree: &mut Option<HashTree>,
        stats: &mut SyncStats,
    ) -> Result<()> {
        // fetch every shard frame on the pool so fabric latency
        // overlaps across shards (the publisher's upload path does the
        // same)
        let tr = &self.transport;
        let fetched: Vec<Result<Vec<u8>>> =
            pool::par_map((0..shard_count).collect(), |_, i| tr.fetch_shard(step, i));
        let mut objs = Vec::with_capacity(fetched.len());
        for (i, r) in fetched.into_iter().enumerate() {
            let obj = match r {
                Ok(obj) => {
                    stats.bytes_downloaded += obj.len() as u64;
                    obj
                }
                Err(_) => {
                    // transport-level loss: one repair fetch (which
                    // counts its own bytes) before abandoning the step
                    stats.shard_refetches += 1;
                    self.refetch_shard(step, i as u32, stats)?
                }
            };
            objs.push(obj);
        }
        let layout = &self.layout;
        let decoded: Vec<Result<Patch>> =
            pool::par_map(objs, |_, obj| container::decode(&obj, layout));
        let mut patches = Vec::with_capacity(decoded.len());
        for (i, d) in decoded.into_iter().enumerate() {
            match d {
                Ok(p) => patches.push(p),
                Err(_) => {
                    // transport/store-level corruption: one refetch
                    stats.shard_refetches += 1;
                    let obj = self.refetch_shard(step, i as u32, stats)?;
                    patches.push(container::decode(&obj, layout).with_context(|| {
                        format!("shard {} of step {} after refetch", i, step)
                    })?);
                }
            }
        }
        let ce = validate_shard_set(&patches, step, shard_count, expect_root, w.len())?;
        let mut ht = match tree.take() {
            Some(ht) if ht.chunk_elems() == ce && ht.total_elems() == w.len() => ht,
            _ => HashTree::build(w, ce),
        };
        let refs: Vec<ShardPatchRef> = patches.iter().map(shard_ref).collect();
        let verified = ht.apply_and_rehash_shards(w, &refs);
        for (i, ok) in verified.iter().enumerate() {
            if *ok {
                continue;
            }
            // the failed shard was restored exactly; refetch it alone
            // while every other shard stays applied
            stats.shard_refetches += 1;
            let obj = self.refetch_shard(step, i as u32, stats)?;
            let retry = container::decode(&obj, layout)
                .with_context(|| format!("shard {} of step {} after refetch", i, step))?;
            validate_shard_retry(&retry, &patches[i])?;
            let ok2 = ht.apply_and_rehash_shards(w, &[shard_ref(&retry)]);
            if !ok2[0] {
                bail!("shard {} of step {} failed verification after refetch", i, step);
            }
        }
        if ht.root_hex() != expect_root {
            bail!("assembled shard root mismatch at step {}", step);
        }
        *tree = Some(ht);
        // the whole step verified against the marker root: every shard
        // is now applied, so each gets its apply span here
        for i in 0..shard_count {
            crate::obs::span(
                crate::obs::Stage::Apply,
                stats.generation,
                step,
                i,
                shard_count as u64,
            );
        }
        Ok(())
    }
}

/// Borrow a validated sharded patch as a hashtree shard apply.
fn shard_ref(p: &Patch) -> ShardPatchRef<'_> {
    let values = match &p.values {
        Values::Bf16(v) => v.as_slice(),
        // validate_shard_set rejects non-bf16 shards before this runs
        Values::F32(_) => &[],
    };
    ShardPatchRef {
        elem_lo: p.elem_offset as usize,
        elem_hi: (p.elem_offset + p.elem_len) as usize,
        indices: &p.indices,
        values,
        expect_root: &p.shard_root,
    }
}

/// Validate a decoded shard set against the marker and local state:
/// complete partition of `0..total` in index order, chunk-aligned,
/// consistent geometry, strictly sorted in-range indices, and every
/// frame bound to the same global root. Returns the (shared)
/// hash-tree chunk size. Anything inconsistent is a hard error — the
/// caller falls back to the anchor slow path rather than trusting wire
/// geometry.
fn validate_shard_set(
    patches: &[Patch],
    step: u64,
    shard_count: u32,
    expect_root: &str,
    total: usize,
) -> Result<usize> {
    if patches.len() != shard_count as usize {
        bail!("expected {} shards, decoded {}", shard_count, patches.len());
    }
    let ce = patches[0].chunk_elems as usize;
    let mut next_lo = 0u64;
    for (i, p) in patches.iter().enumerate() {
        if p.step != step {
            bail!("shard {} carries step {}, want {}", i, p.step, step);
        }
        if p.shard_count != shard_count || p.shard_index != i as u32 {
            bail!("shard header mismatch at frame {} of step {}", i, step);
        }
        if p.total_params != total as u64 {
            bail!("shard {} total_params {} != local {}", i, p.total_params, total);
        }
        if p.chunk_elems as usize != ce || ce == 0 {
            bail!("inconsistent hash-tree geometry across shards of step {}", step);
        }
        if p.result_hash != expect_root {
            bail!("shard {} global root disagrees with marker at step {}", i, step);
        }
        if p.shard_root.len() != 64 {
            bail!("shard {} missing subtree root", i);
        }
        if !matches!(p.values, Values::Bf16(_)) {
            bail!("shard {} carries non-bf16 values", i);
        }
        if p.elem_offset != next_lo {
            bail!("shard ranges of step {} do not partition the buffer", step);
        }
        if p.elem_offset % ce as u64 != 0 {
            bail!("shard {} range not chunk-aligned", i);
        }
        let hi = p
            .elem_offset
            .checked_add(p.elem_len)
            .ok_or_else(|| anyhow::anyhow!("shard {} range overflows", i))?;
        if hi > total as u64 || (hi % ce as u64 != 0 && hi != total as u64) {
            bail!("shard {} range end not chunk-aligned", i);
        }
        validate_shard_indices(p)?;
        next_lo = hi;
    }
    if next_lo != total as u64 {
        bail!("shard ranges of step {} do not cover the buffer", step);
    }
    Ok(ce)
}

/// Strictly sorted indices inside the shard's declared range (protects
/// the parallel apply, which asserts these invariants, from corrupted
/// index streams).
fn validate_shard_indices(p: &Patch) -> Result<()> {
    let lo = p.elem_offset;
    let hi = p.elem_offset + p.elem_len;
    let mut prev: Option<u64> = None;
    for &i in &p.indices {
        if i < lo || i >= hi {
            bail!("shard {} index {} outside range {}..{}", p.shard_index, i, lo, hi);
        }
        if let Some(prev) = prev {
            if i <= prev {
                bail!("shard {} index stream not strictly sorted", p.shard_index);
            }
        }
        prev = Some(i);
    }
    if p.indices.len() != p.values.len() {
        bail!("shard {} index/value length mismatch", p.shard_index);
    }
    Ok(())
}

/// A refetched shard must describe the same slot as the frame it
/// replaces (the original geometry already passed partition checks).
fn validate_shard_retry(retry: &Patch, original: &Patch) -> Result<()> {
    if retry.step != original.step
        || retry.shard_index != original.shard_index
        || retry.shard_count != original.shard_count
        || retry.elem_offset != original.elem_offset
        || retry.elem_len != original.elem_len
        || retry.chunk_elems != original.chunk_elems
        || retry.result_hash != original.result_hash
        || !matches!(retry.values, Values::Bf16(_))
    {
        bail!("refetched shard {} disagrees with its slot", original.shard_index);
    }
    validate_shard_indices(retry)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::transport::{delta_key, delta_shard_key, InProcTransport};
    use crate::sparse::synthetic_layout;
    use crate::util::rng::Rng;

    fn setup(n: usize, k: u64) -> (Publisher, Consumer, ObjectStore, Vec<u16>, Rng) {
        let store = ObjectStore::temp("pulsesync").unwrap();
        let layout = synthetic_layout(n, 64);
        let rng = Rng::new(1);
        let mut r2 = Rng::new(2);
        let init: Vec<u16> = (0..n)
            .map(|_| crate::bf16::f32_to_bf16_bits(r2.normal() as f32 * 0.02))
            .collect();
        let publisher =
            Publisher::new(store.clone(), "sync", layout.clone(), init.clone(), k).unwrap();
        let consumer = Consumer::new(store.clone(), "sync", layout);
        (publisher, consumer, store, init, rng)
    }

    fn perturb(rng: &mut Rng, w: &mut [u16], count: usize) {
        for _ in 0..count {
            let i = rng.below(w.len() as u64) as usize;
            w[i] = crate::bf16::f32_to_bf16_bits(rng.normal() as f32 * 0.02);
        }
    }

    #[test]
    fn fast_path_bit_identical() {
        let (mut p, mut c, _store, mut w, mut rng) = setup(10_000, 50);
        // cold start
        let s0 = c.synchronize().unwrap();
        assert_eq!(s0.path, SyncPath::Slow);
        assert_eq!(s0.transport, "object-store");
        assert_eq!(c.weights.as_ref().unwrap(), &w);
        for step in 1..=5u64 {
            perturb(&mut rng, &mut w, 100);
            let ps = p.publish(step, &w).unwrap();
            assert!(ps.sparsity > 0.9);
            let cs = c.synchronize().unwrap();
            assert_eq!(cs.path, SyncPath::Fast);
            assert!(cs.verified);
            assert_eq!(c.weights.as_ref().unwrap(), &w, "step {}", step);
        }
    }

    #[test]
    fn chain_path_catches_up() {
        let (mut p, mut c, _store, mut w, mut rng) = setup(5_000, 50);
        c.synchronize().unwrap();
        for step in 1..=7u64 {
            perturb(&mut rng, &mut w, 50);
            p.publish(step, &w).unwrap();
        }
        let cs = c.synchronize().unwrap();
        assert_eq!(cs.path, SyncPath::Chain);
        assert_eq!(cs.patches_applied, 7);
        assert_eq!(c.weights.as_ref().unwrap(), &w);
    }

    #[test]
    fn slow_path_after_retention() {
        let (mut p, mut c, store, mut w, mut rng) = setup(5_000, 5);
        for step in 1..=12u64 {
            perturb(&mut rng, &mut w, 50);
            p.publish(step, &w).unwrap();
        }
        // delete early deltas (simulates retention), keep anchors
        for t in 1..=9u64 {
            store.delete(&format!("sync/{}", delta_key(t))).unwrap();
            store.delete(&format!("sync/delta_ready_{}", t)).unwrap();
        }
        let cs = c.synchronize().unwrap();
        assert_eq!(cs.path, SyncPath::Slow);
        assert_eq!(c.weights.as_ref().unwrap(), &w);
    }

    #[test]
    fn corruption_triggers_self_healing() {
        let (mut p, mut c, store, mut w, mut rng) = setup(5_000, 50);
        c.synchronize().unwrap();
        perturb(&mut rng, &mut w, 50);
        p.publish(1, &w).unwrap();
        // corrupt the delta object; consumer must fall back to anchor 0
        // + ... but anchor 0 has no deltas to reach step 1, so the chain
        // through the corrupt patch fails. Publish step 2 with an anchor
        // to give a recovery point.
        let key = format!("sync/{}", delta_key(1));
        let mut obj = store.get(&key).unwrap();
        let n = obj.len();
        obj[n - 1] ^= 0xFF;
        store.put(&key, &obj).unwrap();
        perturb(&mut rng, &mut w, 50);
        p.fail_next_delta = true; // step 2 becomes an anchor (J.5)
        p.publish(2, &w).unwrap();
        let cs = c.synchronize().unwrap();
        assert_eq!(cs.path, SyncPath::Slow);
        assert!(cs.verified);
        assert_eq!(c.weights.as_ref().unwrap(), &w);
    }

    #[test]
    fn delta_upload_failure_recovery() {
        let (mut p, mut c, _store, mut w, mut rng) = setup(5_000, 100);
        c.synchronize().unwrap();
        perturb(&mut rng, &mut w, 50);
        p.publish(1, &w).unwrap();
        perturb(&mut rng, &mut w, 50);
        p.fail_next_delta = true;
        p.publish(2, &w).unwrap(); // anchor instead of delta
        perturb(&mut rng, &mut w, 50);
        p.publish(3, &w).unwrap();
        let cs = c.synchronize().unwrap();
        assert_eq!(c.weights.as_ref().unwrap(), &w);
        assert_eq!(cs.to_step, 3);
    }

    #[test]
    fn stats_split_patches_from_anchor_restarts() {
        let (mut p, mut c, _store, mut w, mut rng) = setup(5_000, 100);
        let s0 = c.synchronize().unwrap();
        // cold start restores exactly one anchor, applies no patches
        assert_eq!(s0.anchors_restored, 1);
        assert_eq!(s0.patches_applied, 0);
        perturb(&mut rng, &mut w, 50);
        p.publish(1, &w).unwrap();
        perturb(&mut rng, &mut w, 50);
        p.fail_next_delta = true;
        p.publish(2, &w).unwrap(); // anchor instead of delta (§J.5)
        perturb(&mut rng, &mut w, 50);
        p.publish(3, &w).unwrap();
        let cs = c.synchronize().unwrap();
        assert_eq!(c.weights.as_ref().unwrap(), &w);
        assert_eq!(cs.patches_applied, 2, "steps 1 and 3 are deltas");
        assert_eq!(cs.anchors_restored, 1, "step 2 is an anchor restart");
    }

    #[test]
    fn fast_path_verifies_with_hash_tree() {
        // every delta published by the current Publisher carries v2
        // hash-tree geometry, and the consumer keeps a tree so the fast
        // path never rebuilds from scratch
        let (mut p, mut c, store, mut w, mut rng) = setup(8_000, 50);
        c.synchronize().unwrap();
        assert!(c.tree.is_some(), "slow path must leave a tree behind");
        for step in 1..=3u64 {
            perturb(&mut rng, &mut w, 80);
            p.publish(step, &w).unwrap();
            let obj = store.get(&format!("sync/{}", delta_key(step))).unwrap();
            let patch = container::decode(&obj, &c.layout).unwrap();
            assert_eq!(patch.chunk_elems, DEFAULT_CHUNK_ELEMS as u64);
            assert_eq!(patch.result_hash.len(), 64);
            let cs = c.synchronize().unwrap();
            assert_eq!(cs.path, SyncPath::Fast);
            assert!(c.tree.is_some());
            assert_eq!(
                c.tree.as_ref().unwrap().root_hex(),
                patch.result_hash,
                "consumer tree tracks the published root"
            );
            assert_eq!(c.weights.as_ref().unwrap(), &w);
        }
    }

    #[test]
    fn legacy_v1_objects_still_sync() {
        // a store written by the pre-hash-tree publisher: scalar-hash
        // delta containers (chunk_elems = 0) and bare-hex anchor markers
        let store = ObjectStore::temp("pulsesync_v1").unwrap();
        let n = 4_000usize;
        let layout = synthetic_layout(n, 64);
        let mut rng = Rng::new(3);
        let w0: Vec<u16> = (0..n).map(|_| rng.next_u32() as u16).collect();
        let raw = u16_as_bytes(&w0);
        let comp = Codec::Zstd1.compress(raw).unwrap();
        let mut obj = Vec::new();
        obj.extend_from_slice(b"PLSA");
        obj.extend_from_slice(&0u64.to_le_bytes());
        obj.extend_from_slice(&(n as u64).to_le_bytes());
        obj.extend_from_slice(&comp);
        store
            .put(&format!("sync/{}", crate::net::transport::anchor_key(0)), &obj)
            .unwrap();
        store.put("sync/anchor_ready_0", sha256_hex(raw).as_bytes()).unwrap();
        let mut w1 = w0.clone();
        perturb(&mut rng, &mut w1, 40);
        let idx = sparse::diff_bf16(&w0, &w1);
        let vals = sparse::gather_u16(&w1, &idx);
        let patch = Patch {
            step: 1,
            base_step: 0,
            total_params: n as u64,
            indices: idx,
            values: Values::Bf16(vals),
            result_hash: sha256_hex(u16_as_bytes(&w1)),
            chunk_elems: 0, // v1 container
            ..Default::default()
        };
        let dobj = container::encode(&patch, &layout, EncodeOpts::default()).unwrap();
        store.put(&format!("sync/{}", delta_key(1)), &dobj).unwrap();
        store
            .put("sync/delta_ready_1", patch.result_hash.as_bytes())
            .unwrap();
        let mut c = Consumer::new(store, "sync", layout);
        let cs = c.synchronize().unwrap();
        assert_eq!(cs.to_step, 1);
        assert!(cs.verified);
        assert_eq!(c.weights.as_ref().unwrap(), &w1);
        assert!(c.tree.is_none(), "v1 chain leaves no tree");
    }

    #[test]
    fn sharded_publish_bit_identical_to_unsharded() {
        // acceptance: sharded apply must produce the same final buffer
        // and the same hash-tree root as the unsharded path
        let n = 40_000usize;
        let store = ObjectStore::temp("pulsesync_shard_eq").unwrap();
        let layout = synthetic_layout(n, 64);
        let mut rng = Rng::new(9);
        let mut r2 = Rng::new(10);
        let init: Vec<u16> = (0..n)
            .map(|_| crate::bf16::f32_to_bf16_bits(r2.normal() as f32 * 0.02))
            .collect();
        let mut p1 =
            Publisher::new(store.clone(), "plain", layout.clone(), init.clone(), 50).unwrap();
        let mut p4 = Publisher::new(store.clone(), "sharded", layout.clone(), init.clone(), 50)
            .unwrap()
            .with_shards(4);
        let mut c1 = Consumer::new(store.clone(), "plain", layout.clone());
        let mut c4 = Consumer::new(store.clone(), "sharded", layout.clone());
        c1.synchronize().unwrap();
        c4.synchronize().unwrap();
        let mut w = init;
        for step in 1..=6u64 {
            perturb(&mut rng, &mut w, 300);
            let s1 = p1.publish(step, &w).unwrap();
            let s4 = p4.publish(step, &w).unwrap();
            assert_eq!(s1.shard_count, 1);
            assert_eq!(s4.shard_count, 4);
            assert_eq!(s4.shard_bytes.len(), 4);
            let r1 = c1.synchronize().unwrap();
            let r4 = c4.synchronize().unwrap();
            assert!(r1.verified && r4.verified);
            assert_eq!(r4.shard_refetches, 0);
            assert_eq!(c1.weights.as_ref().unwrap(), &w, "plain step {}", step);
            assert_eq!(c4.weights.as_ref().unwrap(), c1.weights.as_ref().unwrap());
            assert_eq!(
                c1.tree.as_ref().unwrap().root_hex(),
                c4.tree.as_ref().unwrap().root_hex(),
                "sharded and unsharded roots must agree at step {}",
                step
            );
        }
        // the sharded store really contains per-shard frames + v3 marker
        let marker =
            String::from_utf8(store.get("sharded/delta_ready_6").unwrap()).unwrap();
        assert!(marker.starts_with("v3:4:"), "marker = {}", marker);
        for i in 0..4u32 {
            let obj = store.get(&format!("sharded/{}", delta_shard_key(6, i))).unwrap();
            let meta = container::peek_meta(&obj).unwrap();
            assert_eq!(meta.shard_index, i);
            assert_eq!(meta.shard_count, 4);
        }
    }

    #[test]
    fn sharded_chain_path_catches_up() {
        let (mut p, mut c, _store, mut w, mut rng) = setup(20_000, 50);
        p.shard_count = 3;
        c.synchronize().unwrap();
        for step in 1..=5u64 {
            perturb(&mut rng, &mut w, 200);
            p.publish(step, &w).unwrap();
        }
        let cs = c.synchronize().unwrap();
        assert_eq!(cs.path, SyncPath::Chain);
        assert_eq!(cs.patches_applied, 5);
        assert_eq!(c.weights.as_ref().unwrap(), &w);
        // encoder and consumer agree on the tree end to end
        assert_eq!(c.tree.as_ref().unwrap().root_hex(), p.enc.tree().root_hex());
    }

    #[test]
    fn sharded_corruption_self_heals_via_slow_path() {
        // persistent corruption of one shard object: the single-shard
        // refetch sees the same bad bytes, so the step is abandoned and
        // the consumer recovers from the next anchor (§J.5 pattern)
        let (mut p, mut c, store, mut w, mut rng) = setup(20_000, 50);
        p.shard_count = 4;
        c.synchronize().unwrap();
        perturb(&mut rng, &mut w, 200);
        p.publish(1, &w).unwrap();
        let key = format!("sync/{}", delta_shard_key(1, 2));
        let mut obj = store.get(&key).unwrap();
        let len = obj.len();
        obj[len - 1] ^= 0xFF;
        store.put(&key, &obj).unwrap();
        perturb(&mut rng, &mut w, 200);
        p.fail_next_delta = true; // step 2 becomes an anchor (J.5)
        p.publish(2, &w).unwrap();
        let cs = c.synchronize().unwrap();
        assert_eq!(cs.path, SyncPath::Slow);
        assert!(cs.verified);
        assert!(cs.shard_refetches >= 1, "the bad shard must be re-fetched");
        assert_eq!(c.weights.as_ref().unwrap(), &w);
    }

    #[test]
    fn single_shard_config_stays_wire_compatible() {
        // shard_count = 1 must produce exactly the classic v2 object
        // under the classic key, so old consumers keep working
        let (mut p, mut c, store, mut w, mut rng) = setup(6_000, 50);
        assert_eq!(p.shard_count, 1);
        c.synchronize().unwrap();
        perturb(&mut rng, &mut w, 60);
        p.publish(1, &w).unwrap();
        let obj = store.get(&format!("sync/{}", delta_key(1))).unwrap();
        assert_eq!(obj[4], container::VERSION, "single-shard stays v2");
        let marker = String::from_utf8(store.get("sync/delta_ready_1").unwrap()).unwrap();
        assert_eq!(marker.len(), 64, "unsharded marker stays a bare root hex");
        let cs = c.synchronize().unwrap();
        assert_eq!(cs.path, SyncPath::Fast);
        assert_eq!(c.weights.as_ref().unwrap(), &w);
    }

    #[test]
    fn long_chain_remains_bit_identical() {
        // Prop. H.1: chains of value patches never drift.
        let (mut p, mut c, _store, mut w, mut rng) = setup(2_000, 25);
        c.synchronize().unwrap();
        for step in 1..=60u64 {
            perturb(&mut rng, &mut w, 30);
            p.publish(step, &w).unwrap();
            if step % 7 == 0 {
                c.synchronize().unwrap();
                assert_eq!(c.weights.as_ref().unwrap(), &w, "step {}", step);
            }
        }
        c.synchronize().unwrap();
        assert_eq!(c.weights.as_ref().unwrap(), &w);
    }

    #[test]
    fn balanced_sharding_stays_bit_identical_and_spreads_bytes() {
        // updates concentrated in the first 10% of the buffer: the
        // static split gives shard 0 nearly all payload; the balanced
        // split must spread it while staying bit-identical end to end
        let n = 64_000usize;
        let store = ObjectStore::temp("pulsesync_balance").unwrap();
        let layout = synthetic_layout(n, 64);
        let mut rng = Rng::new(17);
        let init: Vec<u16> = (0..n).map(|_| rng.next_u32() as u16).collect();
        let hot = n / 10;
        let mut p_static = Publisher::new(store.clone(), "st", layout.clone(), init.clone(), 50)
            .unwrap()
            .with_shards(4);
        let mut p_bal = Publisher::new(store.clone(), "bal", layout.clone(), init.clone(), 50)
            .unwrap()
            .with_shards(4)
            .with_shard_balancing(true);
        let mut c_bal = Consumer::new(store.clone(), "bal", layout.clone());
        c_bal.synchronize().unwrap();
        let mut w = init;
        for step in 1..=4u64 {
            for _ in 0..800 {
                let i = rng.below(hot as u64) as usize;
                w[i] = rng.next_u32() as u16;
            }
            let ss = p_static.publish(step, &w).unwrap();
            let sb = p_bal.publish(step, &w).unwrap();
            assert_eq!(sb.shard_count, 4, "balanced split must still use 4 shards");
            let imbalance = |bytes: &[u64]| {
                let total: u64 = bytes.iter().sum();
                let mean = total as f64 / bytes.len() as f64;
                *bytes.iter().max().unwrap() as f64 / mean
            };
            assert!(
                imbalance(&sb.shard_bytes) < imbalance(&ss.shard_bytes),
                "balanced split must beat static on a hot-region stream \
                 (static {:?}, balanced {:?})",
                ss.shard_bytes,
                sb.shard_bytes
            );
            assert!(
                imbalance(&sb.shard_bytes) < 2.0,
                "balanced shard bytes still skewed: {:?}",
                sb.shard_bytes
            );
            let cs = c_bal.synchronize().unwrap();
            assert!(cs.verified);
            assert_eq!(cs.shard_refetches, 0);
            assert_eq!(c_bal.weights.as_ref().unwrap(), &w, "step {}", step);
        }
        // the balanced publisher's tree agrees with the consumer's
        assert_eq!(c_bal.tree.as_ref().unwrap().root_hex(), p_bal.tree().root_hex());
    }

    #[test]
    fn stale_empty_poll_does_not_poison_synchronize() {
        // a latest_ready() taken before anything was published caches
        // an empty snapshot; a later synchronize must rescan instead of
        // failing on the stale cache
        let fabric = InProcTransport::new();
        let layout = synthetic_layout(2_000, 64);
        let mut c = Consumer::over(fabric.clone(), layout.clone());
        assert_eq!(c.latest_ready().unwrap(), None);
        let init: Vec<u16> = (0..2_000u32).map(|i| i as u16).collect();
        let mut p = Publisher::over(fabric, layout, init.clone(), 10).unwrap();
        let mut w = init;
        w[7] ^= 1;
        p.publish(1, &w).unwrap();
        let cs = c.synchronize().unwrap();
        assert!(cs.verified);
        assert_eq!(cs.to_step, 1);
        assert_eq!(c.weights.as_ref().unwrap(), &w);
    }

    #[test]
    fn publisher_restart_resumes_from_anchor_as_next_generation() {
        // crash after step 7 (k = 5, so the newest anchor is step 5):
        // the restarted publisher must resume from anchor 5 as
        // generation 1, and a consumer that followed the dead lineage
        // to step 7 must re-anchor onto the new one without
        // re-applying anything it already holds
        let (mut p, mut c, store, mut w, mut rng) = setup(6_000, 5);
        c.synchronize().unwrap();
        for step in 1..=7u64 {
            perturb(&mut rng, &mut w, 60);
            p.publish(step, &w).unwrap();
        }
        c.synchronize().unwrap();
        assert_eq!(c.step, 7);
        drop(p); // publisher crash
        let (rw, rstep, rgen) =
            recover_anchor_state(&ObjectStoreTransport::new(store.clone(), "sync")).unwrap();
        assert_eq!(rstep, 5);
        assert_eq!(rgen, 0, "the dead lineage was generation 0");
        let mut p2 = Publisher::resume_over(
            ObjectStoreTransport::new(store.clone(), "sync"),
            c.layout.clone(),
            5,
        )
        .unwrap();
        assert_eq!(p2.generation, 1);
        assert_eq!(p2.current_step(), 5);
        assert_eq!(p2.current_weights(), &rw[..]);
        // the lineage rewinds: 6 and 7 are re-published with new
        // content, then training continues past the dead head
        let mut w2 = rw;
        for step in 6..=12u64 {
            perturb(&mut rng, &mut w2, 60);
            p2.publish(step, &w2).unwrap();
        }
        let marker = String::from_utf8(store.get("sync/delta_ready_6").unwrap()).unwrap();
        assert!(marker.starts_with("g1;"), "resumed markers carry the tag: {}", marker);
        let cs = c.synchronize().unwrap();
        assert!(cs.verified);
        assert_eq!(cs.path, SyncPath::Slow, "cross-generation catch-up re-anchors");
        assert_eq!(cs.generation, 1);
        assert_eq!(c.generation, 1);
        assert_eq!(c.weights.as_ref().unwrap(), &w2);
        let again = c.synchronize().unwrap();
        assert_eq!(again.path, SyncPath::UpToDate);
        assert_eq!(again.patches_applied, 0, "no duplicate applies after re-anchor");
    }

    #[test]
    fn head_regression_reanchors_instead_of_no_op() {
        // a consumer ahead of a freshly resumed publisher's head
        // (retention pruned the dead lineage's tail) must rewind to
        // the recovery anchor, not silently report success on stale
        // weights
        let (mut p, mut c, store, mut w, mut rng) = setup(4_000, 5);
        c.synchronize().unwrap();
        let mut w5 = Vec::new();
        for step in 1..=7u64 {
            perturb(&mut rng, &mut w, 40);
            p.publish(step, &w).unwrap();
            if step == 5 {
                w5 = w.clone();
            }
        }
        c.synchronize().unwrap();
        assert_eq!(c.step, 7);
        for t in 6..=7u64 {
            store.delete(&format!("sync/{}", delta_key(t))).unwrap();
            store.delete(&format!("sync/delta_ready_{}", t)).unwrap();
        }
        drop(p);
        let mut p2 = Publisher::resume_over(
            ObjectStoreTransport::new(store.clone(), "sync"),
            c.layout.clone(),
            5,
        )
        .unwrap();
        let cs = c.synchronize().unwrap();
        assert_eq!(cs.path, SyncPath::Slow);
        assert_eq!((cs.from_step, cs.to_step), (7, 5), "head regressed to the anchor");
        assert_eq!(cs.patches_applied, 0, "nothing re-applies on the rewind");
        assert_eq!(c.step, 5);
        assert_eq!(c.generation, 1);
        assert_eq!(c.weights.as_ref().unwrap(), &w5);
        // and the new lineage chains normally from there
        let mut w2 = w5;
        for step in 6..=8u64 {
            perturb(&mut rng, &mut w2, 40);
            p2.publish(step, &w2).unwrap();
        }
        let cs2 = c.synchronize().unwrap();
        assert_eq!(cs2.path, SyncPath::Chain);
        assert_eq!(cs2.patches_applied, 3);
        assert_eq!(c.weights.as_ref().unwrap(), &w2);
    }

    #[test]
    fn generic_publisher_consumer_over_inproc() {
        // the same state machines over the zero-I/O backend; also the
        // single-scan regression: latest_ready + synchronize = 1 scan
        let n = 12_000usize;
        let layout = synthetic_layout(n, 64);
        let mut rng = Rng::new(23);
        let init: Vec<u16> = (0..n).map(|_| rng.next_u32() as u16).collect();
        let fabric = InProcTransport::new();
        let mut p = Publisher::over(fabric.clone(), layout.clone(), init.clone(), 4)
            .unwrap()
            .with_shards(3);
        let mut c = Consumer::over(fabric.clone(), layout);
        let s0 = c.synchronize().unwrap();
        assert_eq!(s0.path, SyncPath::Slow);
        assert_eq!(s0.transport, "in-proc");
        let mut w = init;
        for step in 1..=6u64 {
            perturb(&mut rng, &mut w, 150);
            p.publish(step, &w).unwrap();
            let scans_before = fabric.counters().inventory_scans;
            let head = c.latest_ready().unwrap();
            assert_eq!(head, Some(step));
            let cs = c.synchronize().unwrap();
            assert_eq!(
                fabric.counters().inventory_scans,
                scans_before + 1,
                "latest_ready + synchronize must cost exactly one scan"
            );
            assert!(cs.verified);
            assert_eq!(c.weights.as_ref().unwrap(), &w, "step {}", step);
        }
    }
}
