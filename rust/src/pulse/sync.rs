//! PULSESync: lossless trainer→inference weight synchronization
//! (paper §4.2 + §J).
//!
//! The trainer publishes, per optimizer step, a sparse **value patch**
//! (changed BF16 positions + their new bit patterns) and, every `k`
//! steps, a full **anchor** checkpoint. Inference workers follow the
//! delta stream (fast path: one patch per step) and fall back to
//! anchor + patch-chain on cold start, missed steps, or hash mismatch
//! (slow path, Alg. 5). Reconstruction is a memory overwrite with no
//! floating-point arithmetic, so chained patches stay bit-identical
//! (Prop. H.1).
//!
//! # Verification cost model (§J.4, made O(nnz))
//!
//! Integrity is checked against a chunked hash tree
//! ([`crate::sparse::hashtree`]) instead of a scalar SHA-256 of the
//! whole buffer. Both sides keep the tree alongside their weights, so
//! per step:
//!
//! * the publisher's diff+gather is one fused word-skipping scan and its
//!   root update rehashes only the chunks the patch touches —
//!   O(nnz · chunk_elems) hashing instead of O(total_params);
//! * the consumer's [`crate::sparse::hashtree::HashTree::apply_and_rehash`]
//!   fuses the patch apply with the chunk rehash in one pass and
//!   compares the resulting root to the one in the patch's v2 container
//!   header (chunk size + root; see [`crate::sparse::container`]).
//!
//! Only the slow path still hashes the full buffer (building the tree
//! from a downloaded anchor — a parallel chunked build). Legacy v1
//! containers and plain-hex anchor markers verify via the scalar hash,
//! so stores written before the hash tree still synchronize.

use crate::codec::Codec;
use crate::sparse::container::{self, EncodeOpts, Patch, Values};
use crate::sparse::hashtree::{HashTree, DEFAULT_CHUNK_ELEMS};
use crate::sparse::{self, TensorShape};
use crate::storage::retention::{self, Inventory};
use crate::storage::ObjectStore;
use crate::util::{sha256_hex, u16_as_bytes};
use anyhow::{bail, Context, Result};

/// Key scheme under the publisher prefix.
fn delta_key(step: u64) -> String {
    format!("delta_{:08}.bin", step)
}
fn delta_ready_key(step: u64) -> String {
    format!("delta_ready_{}", step)
}
fn anchor_key(step: u64) -> String {
    format!("anchor_{:08}.bin", step)
}
fn anchor_ready_key(step: u64) -> String {
    format!("anchor_ready_{}", step)
}

/// Anchor ready-marker payload: `v2:<chunk_elems>:<root_hex>` for
/// hash-tree verification. Legacy markers are the bare scalar SHA-256
/// hex of the raw BF16 bytes and still verify.
fn anchor_marker(tree: &HashTree) -> String {
    format!("v2:{}:{}", tree.chunk_elems(), tree.root_hex())
}

fn parse_anchor_marker(s: &str) -> Option<(usize, &str)> {
    let rest = s.strip_prefix("v2:")?;
    let (chunk, root) = rest.split_once(':')?;
    let chunk: usize = chunk.parse().ok()?;
    // untrusted geometry: same wire minimum as the container header, so
    // a corrupted marker fails verification instead of exploding the
    // digest allocation
    if chunk < crate::sparse::hashtree::MIN_WIRE_CHUNK_ELEMS {
        return None;
    }
    Some((chunk, root))
}

/// Publisher-side statistics for one published step.
#[derive(Debug, Clone, Default)]
pub struct PublishStats {
    pub step: u64,
    pub nnz: usize,
    pub total: usize,
    pub patch_bytes: u64,
    pub anchor_bytes: u64,
    pub sparsity: f64,
    pub encode_secs: f64,
}

/// Trainer-side publisher (Alg. 5 `PublishCheckpoint`).
pub struct Publisher {
    pub store: ObjectStore,
    pub prefix: String,
    pub layout: Vec<TensorShape>,
    pub opts: EncodeOpts,
    /// Anchor interval k (paper uses 50).
    pub anchor_interval: u64,
    /// Previous published BF16 view W_{t-1}.
    prev: Vec<u16>,
    prev_step: u64,
    /// Chunked hash tree over `prev`, updated incrementally per publish.
    tree: HashTree,
    /// Test hook: force the next delta upload to fail (§J.5 recovery).
    pub fail_next_delta: bool,
}

impl Publisher {
    /// Create a publisher and publish step 0 as the initial anchor.
    pub fn new(
        store: ObjectStore,
        prefix: &str,
        layout: Vec<TensorShape>,
        initial: Vec<u16>,
        anchor_interval: u64,
    ) -> Result<Publisher> {
        let tree = HashTree::build(&initial, DEFAULT_CHUNK_ELEMS);
        let mut p = Publisher {
            store,
            prefix: prefix.trim_end_matches('/').to_string(),
            layout,
            opts: EncodeOpts::default(),
            anchor_interval: anchor_interval.max(1),
            prev: initial,
            prev_step: 0,
            tree,
            fail_next_delta: false,
        };
        p.upload_anchor(0)?;
        Ok(p)
    }

    fn key(&self, k: String) -> String {
        format!("{}/{}", self.prefix, k)
    }

    pub fn current_step(&self) -> u64 {
        self.prev_step
    }

    pub fn current_weights(&self) -> &[u16] {
        &self.prev
    }

    fn upload_anchor(&mut self, step: u64) -> Result<u64> {
        // Anchor = zstd-1-compressed raw BF16 bytes + 16-byte header.
        let raw = u16_as_bytes(&self.prev);
        let comp = Codec::Zstd1.compress(raw)?;
        let mut obj = Vec::with_capacity(comp.len() + 16);
        obj.extend_from_slice(b"PLSA");
        obj.extend_from_slice(&step.to_le_bytes());
        obj.extend_from_slice(&(self.prev.len() as u64).to_le_bytes());
        obj.extend_from_slice(&comp);
        self.store.put(&self.key(anchor_key(step)), &obj)?;
        // anchor ready marker carries the hash-tree geometry + root
        self.store
            .put(&self.key(anchor_ready_key(step)), anchor_marker(&self.tree).as_bytes())?;
        Ok(obj.len() as u64)
    }

    /// Publish optimizer step `step` whose BF16 view is `new`.
    ///
    /// Uploads the sparse delta first (steady-state critical path), then
    /// the anchor if `step % k == 0` (paper §J.1 "concurrent uploads").
    /// If the delta upload fails, falls back to publishing a full anchor
    /// for this step (§J.5).
    pub fn publish(&mut self, step: u64, new: &[u16]) -> Result<PublishStats> {
        if new.len() != self.prev.len() {
            bail!("checkpoint size changed ({} -> {})", self.prev.len(), new.len());
        }
        if step != self.prev_step + 1 {
            bail!("publish steps must be consecutive ({} after {})", step, self.prev_step);
        }
        let t = crate::util::Stopwatch::start();
        // fused diff+gather, then rehash only the touched chunks: the
        // whole encode front half is O(nnz), not O(total_params)
        let (indices, values) = sparse::diff_gather_bf16(&self.prev, new);
        self.tree.update(new, &indices);
        let result_hash = self.tree.root_hex();
        let patch = Patch {
            step,
            base_step: self.prev_step,
            total_params: new.len() as u64,
            indices,
            values: Values::Bf16(values),
            result_hash,
            chunk_elems: self.tree.chunk_elems() as u64,
        };
        let obj = match container::encode(&patch, &self.layout, self.opts) {
            Ok(obj) => obj,
            Err(e) => {
                // the tree already reflects `new` but `prev` does not;
                // rebuild from `prev` so an abandoned publish leaves the
                // publisher consistent (error path only, O(total))
                self.tree = HashTree::build(&self.prev, self.tree.chunk_elems());
                return Err(e);
            }
        };
        let mut stats = PublishStats {
            step,
            nnz: patch.indices.len(),
            total: new.len(),
            patch_bytes: obj.len() as u64,
            anchor_bytes: 0,
            sparsity: sparse::sparsity(patch.indices.len(), new.len()),
            encode_secs: 0.0,
        };

        self.prev.copy_from_slice(new);
        self.prev_step = step;

        let delta_failed = std::mem::take(&mut self.fail_next_delta);
        if delta_failed {
            // §J.5: delta upload failure → publish a full anchor so the
            // chain stays recoverable, and skip the delta marker.
            stats.anchor_bytes = self.upload_anchor(step)?;
            stats.encode_secs = t.secs();
            return Ok(stats);
        }
        self.store.put(&self.key(delta_key(step)), &obj)?;
        self.store
            .put(&self.key(delta_ready_key(step)), patch.result_hash.as_bytes())?;
        if step % self.anchor_interval == 0 {
            stats.anchor_bytes = self.upload_anchor(step)?;
        }
        stats.encode_secs = t.secs();
        Ok(stats)
    }
}

/// Consumer-side statistics for one synchronize() call.
#[derive(Debug, Clone, Default)]
pub struct SyncStats {
    pub from_step: u64,
    pub to_step: u64,
    pub path: SyncPath,
    /// Total bytes transferred during this call, including any fast-
    /// path attempt that was abandoned for the slow path.
    pub bytes_downloaded: u64,
    /// Sparse delta patches applied on the chain that produced the
    /// final weights (anchor restarts are counted in
    /// `anchors_restored`, not here; an abandoned fast-path attempt
    /// counts toward neither).
    pub patches_applied: usize,
    /// Full anchors downloaded and restored from on that chain: the
    /// slow-path base anchor plus any §J.5 anchor that replaced a
    /// failed delta upload.
    pub anchors_restored: usize,
    pub verified: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SyncPath {
    #[default]
    UpToDate,
    Fast,
    Chain,
    Slow,
}

/// Inference-worker consumer (Alg. 5 `Synchronize`).
pub struct Consumer {
    pub store: ObjectStore,
    pub prefix: String,
    pub layout: Vec<TensorShape>,
    /// Local BF16 weights (None until first slow-path sync).
    pub weights: Option<Vec<u16>>,
    pub step: u64,
    /// Hash tree mirroring `weights`, reused across synchronize() calls
    /// so the fast path verifies in O(nnz · chunk). None until built
    /// from an anchor, or after a legacy v1 patch made it stale.
    tree: Option<HashTree>,
}

/// Latest step with a delta-ready (or anchor-ready) marker in `inv`.
fn latest_of(inv: &Inventory) -> Option<u64> {
    inv.delta_steps
        .last()
        .copied()
        .into_iter()
        .chain(inv.anchor_steps.last().copied())
        .max()
}

impl Consumer {
    pub fn new(store: ObjectStore, prefix: &str, layout: Vec<TensorShape>) -> Consumer {
        Consumer {
            store,
            prefix: prefix.trim_end_matches('/').to_string(),
            layout,
            weights: None,
            step: 0,
            tree: None,
        }
    }

    fn key(&self, k: String) -> String {
        format!("{}/{}", self.prefix, k)
    }

    /// Latest step with a delta-ready (or anchor-ready) marker.
    pub fn latest_ready(&self) -> Result<Option<u64>> {
        Ok(latest_of(&retention::scan(&self.store, &self.prefix)?))
    }

    /// Synchronize to the newest published checkpoint. Implements the
    /// fast path (single patch), chain path (several patches), and slow
    /// path (anchor + chain); falls back to the slow path on any
    /// verification failure (§J.5 self-healing).
    pub fn synchronize(&mut self) -> Result<SyncStats> {
        // one inventory scan serves both the head lookup and the
        // slow-path anchor choice
        let inv = retention::scan(&self.store, &self.prefix)?;
        let latest = match latest_of(&inv) {
            Some(s) => s,
            None => bail!("no checkpoints published under {}", self.prefix),
        };
        let mut stats = SyncStats { from_step: self.step, to_step: latest, ..Default::default() };
        if self.weights.is_some() && latest == self.step {
            stats.path = SyncPath::UpToDate;
            stats.verified = true;
            return Ok(stats);
        }
        if let Some(w) = self.weights.clone() {
            // try fast/chain path: apply deltas step+1 ..= latest
            let tree = self.tree.take();
            match self.apply_chain(w, tree, self.step, latest, &mut stats) {
                Ok((weights, tree)) => {
                    self.weights = Some(weights);
                    self.tree = tree;
                    self.step = latest;
                    stats.path = if latest == stats.from_step + 1 {
                        SyncPath::Fast
                    } else {
                        SyncPath::Chain
                    };
                    stats.verified = true;
                    return Ok(stats);
                }
                Err(_) => {
                    // fall through to slow path; drop the abandoned
                    // attempt's apply counters (the slow path rebuilds
                    // from an anchor) but keep bytes_downloaded — those
                    // bytes really were transferred
                    stats.patches_applied = 0;
                    stats.anchors_restored = 0;
                }
            }
        }
        // slow path: nearest anchor ≤ latest, then chain
        let anchor = inv
            .anchor_steps
            .iter()
            .filter(|&&a| a <= latest)
            .next_back()
            .copied()
            .ok_or_else(|| anyhow::anyhow!("no anchor available for slow path"))?;
        let (w, tree, bytes) = self.download_anchor(anchor)?;
        stats.bytes_downloaded += bytes;
        stats.anchors_restored += 1;
        let (weights, tree) = self.apply_chain(w, tree, anchor, latest, &mut stats)?;
        self.weights = Some(weights);
        self.tree = tree;
        self.step = latest;
        stats.path = SyncPath::Slow;
        stats.verified = true;
        Ok(stats)
    }

    /// Download + verify an anchor, returning its hash tree when the
    /// ready marker carries v2 geometry (legacy scalar markers verify
    /// via the full-buffer hash and return no tree).
    fn download_anchor(&self, step: u64) -> Result<(Vec<u16>, Option<HashTree>, u64)> {
        let obj = self
            .store
            .get(&self.key(anchor_key(step)))
            .with_context(|| format!("anchor {}", step))?;
        if obj.len() < 20 || &obj[0..4] != b"PLSA" {
            bail!("bad anchor header");
        }
        let astep = u64::from_le_bytes(obj[4..12].try_into().unwrap());
        let n = u64::from_le_bytes(obj[12..20].try_into().unwrap()) as usize;
        if astep != step {
            bail!("anchor step mismatch");
        }
        let raw = Codec::Zstd1.decompress(&obj[20..], n * 2)?;
        let w = crate::util::bytes_to_u16(&raw);
        if w.len() != n {
            bail!("anchor length mismatch");
        }
        // verify against the ready marker (and keep the tree it implies)
        let expect = String::from_utf8(self.store.get(&self.key(anchor_ready_key(step)))?)
            .unwrap_or_default();
        let tree = if let Some((chunk_elems, root)) = parse_anchor_marker(&expect) {
            let t = HashTree::build(&w, chunk_elems);
            if t.root_hex() != root {
                bail!("anchor hash mismatch at step {}", step);
            }
            Some(t)
        } else {
            if !expect.is_empty() && expect != sha256_hex(u16_as_bytes(&w)) {
                bail!("anchor hash mismatch at step {}", step);
            }
            None
        };
        Ok((w, tree, obj.len() as u64))
    }

    /// Apply deltas `(from, to]` onto `w`, verifying each patch's
    /// embedded hash-tree root (Alg. 5 lines 25–29) with a fused
    /// apply+rehash over only the touched chunks. Steps whose delta is
    /// missing but which have their own anchor are restarted from that
    /// anchor (delta-upload-failure recovery). Returns the weights and
    /// the tree kept current with them.
    fn apply_chain(
        &self,
        mut w: Vec<u16>,
        mut tree: Option<HashTree>,
        from: u64,
        to: u64,
        stats: &mut SyncStats,
    ) -> Result<(Vec<u16>, Option<HashTree>)> {
        for t in from + 1..=to {
            if !self.store.exists(&self.key(delta_ready_key(t))) {
                // §J.5: a failed delta upload was replaced by an anchor.
                let (aw, atree, bytes) = self.download_anchor(t)?;
                w = aw;
                tree = atree;
                stats.bytes_downloaded += bytes;
                stats.anchors_restored += 1;
                continue;
            }
            let obj = self.store.get(&self.key(delta_key(t)))?;
            stats.bytes_downloaded += obj.len() as u64;
            let patch = container::decode(&obj, &self.layout)?;
            if patch.step != t {
                bail!("patch step mismatch: got {}, want {}", patch.step, t);
            }
            let values = match &patch.values {
                Values::Bf16(v) => v,
                _ => bail!("weight patch carries non-bf16 values"),
            };
            if patch.chunk_elems > 0 {
                // v2: fused apply + chunk rehash, O(nnz · chunk) verify.
                // Rebuild the tree only when absent or its geometry
                // disagrees with the patch header.
                let ce = patch.chunk_elems as usize;
                let mut ht = match tree.take() {
                    Some(ht) if ht.chunk_elems() == ce && ht.total_elems() == w.len() => ht,
                    _ => HashTree::build(&w, ce),
                };
                ht.apply_and_rehash(&mut w, &patch.indices, values);
                if ht.root_hex() != patch.result_hash {
                    bail!("hash mismatch after applying patch {}", t);
                }
                tree = Some(ht);
            } else {
                // legacy v1: scalar full-buffer hash
                sparse::apply_u16(&mut w, &patch.indices, values);
                if sha256_hex(u16_as_bytes(&w)) != patch.result_hash {
                    bail!("hash mismatch after applying patch {}", t);
                }
                tree = None;
            }
            stats.patches_applied += 1;
        }
        Ok((w, tree))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::synthetic_layout;
    use crate::util::rng::Rng;

    fn setup(n: usize, k: u64) -> (Publisher, Consumer, Vec<u16>, Rng) {
        let store = ObjectStore::temp("pulsesync").unwrap();
        let layout = synthetic_layout(n, 64);
        let rng = Rng::new(1);
        let mut r2 = Rng::new(2);
        let init: Vec<u16> = (0..n)
            .map(|_| crate::bf16::f32_to_bf16_bits(r2.normal() as f32 * 0.02))
            .collect();
        let publisher =
            Publisher::new(store.clone(), "sync", layout.clone(), init.clone(), k).unwrap();
        let consumer = Consumer::new(store, "sync", layout);
        (publisher, consumer, init, rng)
    }

    fn perturb(rng: &mut Rng, w: &mut [u16], count: usize) {
        for _ in 0..count {
            let i = rng.below(w.len() as u64) as usize;
            w[i] = crate::bf16::f32_to_bf16_bits(rng.normal() as f32 * 0.02);
        }
    }

    #[test]
    fn fast_path_bit_identical() {
        let (mut p, mut c, mut w, mut rng) = setup(10_000, 50);
        // cold start
        let s0 = c.synchronize().unwrap();
        assert_eq!(s0.path, SyncPath::Slow);
        assert_eq!(c.weights.as_ref().unwrap(), &w);
        for step in 1..=5u64 {
            perturb(&mut rng, &mut w, 100);
            let ps = p.publish(step, &w).unwrap();
            assert!(ps.sparsity > 0.9);
            let cs = c.synchronize().unwrap();
            assert_eq!(cs.path, SyncPath::Fast);
            assert!(cs.verified);
            assert_eq!(c.weights.as_ref().unwrap(), &w, "step {}", step);
        }
    }

    #[test]
    fn chain_path_catches_up() {
        let (mut p, mut c, mut w, mut rng) = setup(5_000, 50);
        c.synchronize().unwrap();
        for step in 1..=7u64 {
            perturb(&mut rng, &mut w, 50);
            p.publish(step, &w).unwrap();
        }
        let cs = c.synchronize().unwrap();
        assert_eq!(cs.path, SyncPath::Chain);
        assert_eq!(cs.patches_applied, 7);
        assert_eq!(c.weights.as_ref().unwrap(), &w);
    }

    #[test]
    fn slow_path_after_retention() {
        let (mut p, mut c, mut w, mut rng) = setup(5_000, 5);
        for step in 1..=12u64 {
            perturb(&mut rng, &mut w, 50);
            p.publish(step, &w).unwrap();
        }
        // delete early deltas (simulates retention), keep anchors
        for t in 1..=9u64 {
            p.store.delete(&format!("sync/{}", delta_key(t))).unwrap();
            p.store.delete(&format!("sync/delta_ready_{}", t)).unwrap();
        }
        let cs = c.synchronize().unwrap();
        assert_eq!(cs.path, SyncPath::Slow);
        assert_eq!(c.weights.as_ref().unwrap(), &w);
    }

    #[test]
    fn corruption_triggers_self_healing() {
        let (mut p, mut c, mut w, mut rng) = setup(5_000, 50);
        c.synchronize().unwrap();
        perturb(&mut rng, &mut w, 50);
        p.publish(1, &w).unwrap();
        // corrupt the delta object; consumer must fall back to anchor 0
        // + ... but anchor 0 has no deltas to reach step 1, so the chain
        // through the corrupt patch fails. Publish step 2 with an anchor
        // to give a recovery point.
        let key = format!("sync/{}", delta_key(1));
        let mut obj = p.store.get(&key).unwrap();
        let n = obj.len();
        obj[n - 1] ^= 0xFF;
        p.store.put(&key, &obj).unwrap();
        perturb(&mut rng, &mut w, 50);
        p.fail_next_delta = true; // step 2 becomes an anchor (J.5)
        p.publish(2, &w).unwrap();
        let cs = c.synchronize().unwrap();
        assert_eq!(cs.path, SyncPath::Slow);
        assert!(cs.verified);
        assert_eq!(c.weights.as_ref().unwrap(), &w);
    }

    #[test]
    fn delta_upload_failure_recovery() {
        let (mut p, mut c, mut w, mut rng) = setup(5_000, 100);
        c.synchronize().unwrap();
        perturb(&mut rng, &mut w, 50);
        p.publish(1, &w).unwrap();
        perturb(&mut rng, &mut w, 50);
        p.fail_next_delta = true;
        p.publish(2, &w).unwrap(); // anchor instead of delta
        perturb(&mut rng, &mut w, 50);
        p.publish(3, &w).unwrap();
        let cs = c.synchronize().unwrap();
        assert_eq!(c.weights.as_ref().unwrap(), &w);
        assert_eq!(cs.to_step, 3);
    }

    #[test]
    fn stats_split_patches_from_anchor_restarts() {
        let (mut p, mut c, mut w, mut rng) = setup(5_000, 100);
        let s0 = c.synchronize().unwrap();
        // cold start restores exactly one anchor, applies no patches
        assert_eq!(s0.anchors_restored, 1);
        assert_eq!(s0.patches_applied, 0);
        perturb(&mut rng, &mut w, 50);
        p.publish(1, &w).unwrap();
        perturb(&mut rng, &mut w, 50);
        p.fail_next_delta = true;
        p.publish(2, &w).unwrap(); // anchor instead of delta (§J.5)
        perturb(&mut rng, &mut w, 50);
        p.publish(3, &w).unwrap();
        let cs = c.synchronize().unwrap();
        assert_eq!(c.weights.as_ref().unwrap(), &w);
        assert_eq!(cs.patches_applied, 2, "steps 1 and 3 are deltas");
        assert_eq!(cs.anchors_restored, 1, "step 2 is an anchor restart");
    }

    #[test]
    fn fast_path_verifies_with_hash_tree() {
        // every delta published by the current Publisher carries v2
        // hash-tree geometry, and the consumer keeps a tree so the fast
        // path never rebuilds from scratch
        let (mut p, mut c, mut w, mut rng) = setup(8_000, 50);
        c.synchronize().unwrap();
        assert!(c.tree.is_some(), "slow path must leave a tree behind");
        for step in 1..=3u64 {
            perturb(&mut rng, &mut w, 80);
            p.publish(step, &w).unwrap();
            let obj = p.store.get(&format!("sync/{}", delta_key(step))).unwrap();
            let patch = container::decode(&obj, &c.layout).unwrap();
            assert_eq!(patch.chunk_elems, DEFAULT_CHUNK_ELEMS as u64);
            assert_eq!(patch.result_hash.len(), 64);
            let cs = c.synchronize().unwrap();
            assert_eq!(cs.path, SyncPath::Fast);
            assert!(c.tree.is_some());
            assert_eq!(
                c.tree.as_ref().unwrap().root_hex(),
                patch.result_hash,
                "consumer tree tracks the published root"
            );
            assert_eq!(c.weights.as_ref().unwrap(), &w);
        }
    }

    #[test]
    fn legacy_v1_objects_still_sync() {
        // a store written by the pre-hash-tree publisher: scalar-hash
        // delta containers (chunk_elems = 0) and bare-hex anchor markers
        let store = ObjectStore::temp("pulsesync_v1").unwrap();
        let n = 4_000usize;
        let layout = synthetic_layout(n, 64);
        let mut rng = Rng::new(3);
        let w0: Vec<u16> = (0..n).map(|_| rng.next_u32() as u16).collect();
        let raw = u16_as_bytes(&w0);
        let comp = Codec::Zstd1.compress(raw).unwrap();
        let mut obj = Vec::new();
        obj.extend_from_slice(b"PLSA");
        obj.extend_from_slice(&0u64.to_le_bytes());
        obj.extend_from_slice(&(n as u64).to_le_bytes());
        obj.extend_from_slice(&comp);
        store.put(&format!("sync/{}", anchor_key(0)), &obj).unwrap();
        store
            .put(&format!("sync/{}", anchor_ready_key(0)), sha256_hex(raw).as_bytes())
            .unwrap();
        let mut w1 = w0.clone();
        perturb(&mut rng, &mut w1, 40);
        let idx = sparse::diff_bf16(&w0, &w1);
        let vals = sparse::gather_u16(&w1, &idx);
        let patch = Patch {
            step: 1,
            base_step: 0,
            total_params: n as u64,
            indices: idx,
            values: Values::Bf16(vals),
            result_hash: sha256_hex(u16_as_bytes(&w1)),
            chunk_elems: 0, // v1 container
        };
        let dobj = container::encode(&patch, &layout, EncodeOpts::default()).unwrap();
        store.put(&format!("sync/{}", delta_key(1)), &dobj).unwrap();
        store
            .put(&format!("sync/{}", delta_ready_key(1)), patch.result_hash.as_bytes())
            .unwrap();
        let mut c = Consumer::new(store, "sync", layout);
        let cs = c.synchronize().unwrap();
        assert_eq!(cs.to_step, 1);
        assert!(cs.verified);
        assert_eq!(c.weights.as_ref().unwrap(), &w1);
        assert!(c.tree.is_none(), "v1 chain leaves no tree");
    }

    #[test]
    fn long_chain_remains_bit_identical() {
        // Prop. H.1: chains of value patches never drift.
        let (mut p, mut c, mut w, mut rng) = setup(2_000, 25);
        c.synchronize().unwrap();
        for step in 1..=60u64 {
            perturb(&mut rng, &mut w, 30);
            p.publish(step, &w).unwrap();
            if step % 7 == 0 {
                c.synchronize().unwrap();
                assert_eq!(c.weights.as_ref().unwrap(), &w, "step {}", step);
            }
        }
        c.synchronize().unwrap();
        assert_eq!(c.weights.as_ref().unwrap(), &w);
    }
}
