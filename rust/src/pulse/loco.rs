//! PULSELoCo: error-feedback pseudo-gradient synchronization
//! (paper §4.3, Algorithm 2).
//!
//! Each outer round: every worker runs `H` local AdamW steps from the
//! shared parameters θ^(t−1), forms the pseudo-gradient
//! Δ_r = θ^(t−1) − w_r, adds its FP32 error-feedback buffer, gates the
//! sum with the BF16 compute-visibility gate, and synchronizes only the
//! selected FP32 entries. `SPARSESYNC` returns the union support with
//! values averaged over all R workers (missing entries count as zero).
//! The outer Sutskever-Nesterov optimizer (µ=0.9, α=0.7) is applied to
//! the aggregate *after* synchronization, so momentum tracks the same
//! global update as DiLoCo.

use crate::bf16::Dtype;
use crate::codec::Codec;
use crate::gate::feedback::ErrorFeedback;
use crate::optim::Nesterov;
use crate::sparse::container::{self, EncodeOpts, Patch, Values};
use crate::sparse::PatchFormat;
use anyhow::Result;

/// One worker's sparse contribution for a round.
#[derive(Debug, Clone)]
pub struct SparseContribution {
    pub indices: Vec<u64>,
    pub values: Vec<f32>,
}

/// SPARSESYNC (Alg. 2 line 13): union support, FP32 average over all R
/// workers with missing entries treated as zeros.
pub fn sparse_sync(contribs: &[SparseContribution]) -> SparseContribution {
    let r = contribs.len().max(1) as f32;
    // k-way merge over sorted index lists
    let mut cursors = vec![0usize; contribs.len()];
    let mut out_idx = Vec::new();
    let mut out_val = Vec::new();
    loop {
        let mut next: Option<u64> = None;
        for (c, contrib) in contribs.iter().enumerate() {
            if let Some(&i) = contrib.indices.get(cursors[c]) {
                next = Some(next.map_or(i, |n: u64| n.min(i)));
            }
        }
        let Some(i) = next else { break };
        let mut sum = 0.0f32;
        for (c, contrib) in contribs.iter().enumerate() {
            if contrib.indices.get(cursors[c]) == Some(&i) {
                sum += contrib.values[cursors[c]];
                cursors[c] += 1;
            }
        }
        out_idx.push(i);
        out_val.push(sum / r);
    }
    SparseContribution { indices: out_idx, values: out_val }
}

/// Communication accounting for one worker's payload (paper §F.3):
/// delta-varint indices + raw FP32 values, optionally byte-codec'd.
pub fn payload_bytes(
    contrib: &SparseContribution,
    total_params: u64,
    codec: Codec,
    shuffle: bool,
) -> Result<u64> {
    let patch = Patch {
        step: 0,
        base_step: 0,
        total_params,
        indices: contrib.indices.clone(),
        values: Values::F32(contrib.values.clone()),
        // pseudo-gradients are not checkpoints: no result hash, so the
        // container stays v1-framed (chunk_elems = 0) on the wire
        result_hash: String::new(),
        chunk_elems: 0,
        ..Default::default()
    };
    let layout = crate::sparse::synthetic_layout(total_params as usize, 1 << 16);
    let obj = container::encode(
        &patch,
        &layout,
        EncodeOpts { format: PatchFormat::FlatVarint, codec, shuffle_values: shuffle },
    )?;
    Ok(obj.len() as u64)
}

/// Per-round metrics for one worker.
#[derive(Debug, Clone, Default)]
pub struct RoundStats {
    pub round: u64,
    /// Pseudo-gradient communication sparsity after error feedback.
    pub comm_sparsity: f64,
    /// Bytes of the encoded sparse payload (delta-varint + raw FP32).
    pub raw_payload_bytes: u64,
    /// Bytes after zstd-1 on the packed stream.
    pub encoded_payload_bytes: u64,
    /// Bytes after byte-shuffle + zstd-3 (paper §F.3's best variant).
    pub shuffled_zstd3_bytes: u64,
    /// Dense FP32 baseline bytes (N × 4) for the same cadence.
    pub dense_bytes: u64,
    /// L1 mass left in the error buffer.
    pub residual_l1: f64,
}

/// The synchronization strategy for the outer round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OuterMethod {
    /// Dense FP32 pseudo-gradient average (DiLoCo).
    DiLoCo,
    /// BF16-gated sparse pseudo-gradients with error feedback.
    PulseLoCo,
}

/// Outer-loop state shared by DiLoCo and PULSELoCo: global parameters,
/// Nesterov momentum, and per-worker error-feedback buffers.
pub struct OuterLoop {
    pub method: OuterMethod,
    pub theta: Vec<f32>,
    pub outer: Nesterov,
    pub feedback: Vec<ErrorFeedback>,
    pub round: u64,
    /// Dtype for the gate (BF16 in the paper's main setting).
    pub gate_dtype: Dtype,
}

impl OuterLoop {
    pub fn new(method: OuterMethod, theta: Vec<f32>, workers: usize) -> OuterLoop {
        let n = theta.len();
        OuterLoop {
            method,
            outer: Nesterov::new(n),
            feedback: (0..workers).map(|_| ErrorFeedback::new(n, Dtype::Bf16)).collect(),
            theta,
            round: 0,
            gate_dtype: Dtype::Bf16,
        }
    }

    pub fn num_workers(&self) -> usize {
        self.feedback.len()
    }

    /// Complete one outer round given each worker's locally-reached
    /// parameters `w_r` (after H local steps from `self.theta`).
    /// Returns per-worker stats. Updates `self.theta` in place.
    pub fn round(&mut self, local_params: &[Vec<f32>]) -> Result<Vec<RoundStats>> {
        assert_eq!(local_params.len(), self.num_workers());
        let n = self.theta.len();
        self.round += 1;
        let dense_bytes = 4 * n as u64;
        let mut stats = Vec::with_capacity(local_params.len());
        let contribs: Vec<SparseContribution> = match self.method {
            OuterMethod::DiLoCo => local_params
                .iter()
                .map(|w| {
                    // dense pseudo-gradient Δ_r = θ − w_r
                    let delta: Vec<f32> =
                        self.theta.iter().zip(w).map(|(&t, &wi)| t - wi).collect();
                    SparseContribution { indices: (0..n as u64).collect(), values: delta }
                })
                .collect(),
            OuterMethod::PulseLoCo => {
                let theta = &self.theta;
                local_params
                    .iter()
                    .zip(self.feedback.iter_mut())
                    .map(|(w, ef)| {
                        let delta: Vec<f32> =
                            theta.iter().zip(w).map(|(&t, &wi)| t - wi).collect();
                        let gated = ef.gate_and_update(theta, &delta);
                        SparseContribution { indices: gated.indices, values: gated.values }
                    })
                    .collect()
            }
        };
        for (r, c) in contribs.iter().enumerate() {
            let raw = payload_bytes(c, n as u64, Codec::None, false)?;
            let enc = payload_bytes(c, n as u64, Codec::Zstd1, false)?;
            let shuf = payload_bytes(c, n as u64, Codec::Zstd3, true)?;
            stats.push(RoundStats {
                round: self.round,
                comm_sparsity: 1.0 - c.indices.len() as f64 / n as f64,
                raw_payload_bytes: raw,
                encoded_payload_bytes: enc,
                shuffled_zstd3_bytes: shuf,
                dense_bytes,
                residual_l1: match self.method {
                    OuterMethod::PulseLoCo => self.feedback[r].residual_l1(),
                    OuterMethod::DiLoCo => 0.0,
                },
            });
        }
        // aggregate + outer step
        let agg = sparse_sync(&contribs);
        let mut g = vec![0.0f32; n];
        for (&i, &v) in agg.indices.iter().zip(&agg.values) {
            g[i as usize] = v;
        }
        self.outer.step(&mut self.theta, &g);
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn contrib(idx: &[u64], val: &[f32]) -> SparseContribution {
        SparseContribution { indices: idx.to_vec(), values: val.to_vec() }
    }

    #[test]
    fn sparse_sync_union_and_average() {
        let a = contrib(&[1, 3, 5], &[1.0, 1.0, 1.0]);
        let b = contrib(&[3, 4], &[3.0, 2.0]);
        let out = sparse_sync(&[a, b]);
        assert_eq!(out.indices, vec![1, 3, 4, 5]);
        // missing entries are zeros: avg over R=2
        assert_eq!(out.values, vec![0.5, 2.0, 1.0, 0.5]);
    }

    #[test]
    fn sparse_sync_matches_dense_reference() {
        crate::util::prop::check("sparsesync == dense avg", 30, |g| {
            let n = 200;
            let r = 1 + g.rng.below(5) as usize;
            let mut dense = vec![vec![0.0f32; n]; r];
            let mut contribs = Vec::new();
            for w in 0..r {
                let count = g.rng.below(n as u64 / 2) as usize;
                let idx = g.sorted_indices(n, count);
                let vals: Vec<f32> = idx.iter().map(|_| g.rng.normal() as f32).collect();
                for (&i, &v) in idx.iter().zip(&vals) {
                    dense[w][i as usize] = v;
                }
                contribs.push(contrib(&idx, &vals));
            }
            let out = sparse_sync(&contribs);
            let mut expect = vec![0.0f32; n];
            for w in 0..r {
                for i in 0..n {
                    expect[i] += dense[w][i] / r as f32;
                }
            }
            let mut got = vec![0.0f32; n];
            for (&i, &v) in out.indices.iter().zip(&out.values) {
                got[i as usize] = v;
            }
            for i in 0..n {
                assert!((got[i] - expect[i]).abs() < 1e-6, "i={}", i);
            }
        });
    }

    /// When every pseudo-gradient entry passes the gate, PULSELoCo must
    /// produce *exactly* DiLoCo's trajectory.
    #[test]
    fn pulseloco_equals_diloco_when_gate_passes_all() {
        let mut rng = Rng::new(7);
        let n = 500;
        // |θ| ∈ [0.5, 2] and 10%-of-|θ| local updates: every entry is
        // far above the BF16 cell radius (≈|θ|/256), so the gate passes
        // everything and the two methods must coincide bit-for-bit.
        let theta0: Vec<f32> = (0..n)
            .map(|_| {
                let sign = if rng.f64() < 0.5 { -1.0 } else { 1.0 };
                sign * (0.5 + 1.5 * rng.f32())
            })
            .collect();
        let mut diloco = OuterLoop::new(OuterMethod::DiLoCo, theta0.clone(), 3);
        let mut ploco = OuterLoop::new(OuterMethod::PulseLoCo, theta0.clone(), 3);
        for _ in 0..5 {
            let mk = |theta: &[f32]| -> Vec<Vec<f32>> {
                (0..3).map(|_| theta.iter().map(|&t| t * 0.9).collect()).collect()
            };
            let s1 = diloco.round(&mk(&diloco.theta.clone())).unwrap();
            let s2 = ploco.round(&mk(&ploco.theta.clone())).unwrap();
            assert!(s2[0].comm_sparsity < 0.05, "sparsity {}", s2[0].comm_sparsity);
            for i in 0..n {
                assert!(
                    (diloco.theta[i] - ploco.theta[i]).abs() < 1e-6,
                    "i={} {} vs {}",
                    i,
                    diloco.theta[i],
                    ploco.theta[i]
                );
            }
            let _ = s1;
        }
    }

    /// Tiny local updates are buffered, then released once accumulated —
    /// total applied update converges to DiLoCo's (error feedback works).
    #[test]
    fn error_feedback_catches_up() {
        let n = 100;
        let theta0 = vec![1.0f32; n];
        let mut diloco = OuterLoop::new(OuterMethod::DiLoCo, theta0.clone(), 2);
        let mut ploco = OuterLoop::new(OuterMethod::PulseLoCo, theta0.clone(), 2);
        // constant tiny local drift: each round w = theta - 2e-4
        // (sub-cell at |w|=1: cell radius ≈ 3.9e-3)
        for _ in 0..200 {
            let ld: Vec<Vec<f32>> =
                (0..2).map(|_| diloco.theta.iter().map(|&t| t - 2e-4).collect()).collect();
            diloco.round(&ld).unwrap();
            let lp: Vec<Vec<f32>> =
                (0..2).map(|_| ploco.theta.iter().map(|&t| t - 2e-4).collect()).collect();
            ploco.round(&lp).unwrap();
        }
        // both drift upward ~ equally (within a few buffered cells)
        for i in 0..n {
            let gap = (diloco.theta[i] - ploco.theta[i]).abs();
            assert!(gap < 0.02, "i={} diloco {} ploco {}", i, diloco.theta[i], ploco.theta[i]);
        }
        // and PULSELoCo actually moved (didn't swallow everything)
        assert!((ploco.theta[0] - 1.0).abs() > 0.01, "theta {}", ploco.theta[0]);
    }

    #[test]
    fn payload_accounting_sane() {
        let mut rng = Rng::new(9);
        let n = 100_000u64;
        let idx: Vec<u64> = {
            let mut v: Vec<u64> = (0..5000).map(|_| rng.below(n)).collect();
            v.sort_unstable();
            v.dedup();
            v
        };
        let vals: Vec<f32> = idx.iter().map(|_| rng.normal() as f32 * 1e-4).collect();
        let c = contrib(&idx, &vals);
        let raw = payload_bytes(&c, n, Codec::None, false).unwrap();
        // ≈ 4 bytes/value + ~1.5 bytes/index + header
        assert!(raw > idx.len() as u64 * 4);
        assert!(raw < idx.len() as u64 * 7 + 200, "raw={}", raw);
        let enc = payload_bytes(&c, n, Codec::Zstd1, false).unwrap();
        assert!(enc <= raw);
    }
}
