//! The PULSE methods (paper §4): one rule — *send only updates that
//! would change the next forward pass* — instantiated as two algorithms.
//!
//! * [`sync`] — **PULSESync**: lossless sparse BF16 weight patches from
//!   trainer to inference workers, over the object store, with anchors,
//!   ready markers, hash verification and failure recovery (Alg. 1/5).
//! * [`loco`] — **PULSELoCo**: DiLoCo-style pseudo-gradient
//!   synchronization sparsified by the BF16 compute-visibility gate with
//!   FP32 error feedback (Alg. 2), including the `SPARSESYNC` collective.

pub mod loco;
pub mod sync;
