//! Codec throughput/ratio micro-bench (feeds Table 5 sanity + §Perf).
use pulse::codec::Codec;
use pulse::util::bench::Bench;
use pulse::util::rng::Rng;

fn patch_like_payload(n_values: usize) -> Vec<u8> {
    // realistic pre-codec patch stream: downscaled COO indices + bf16 values
    let universe = n_values * 100;
    let layout = pulse::sparse::synthetic_layout(universe, 1024);
    let mut rng = Rng::new(5);
    let mut idx: Vec<u64> = (0..n_values).map(|_| rng.below(universe as u64)).collect();
    idx.sort_unstable();
    idx.dedup();
    let vals: Vec<u16> = idx
        .iter()
        .map(|_| pulse::bf16::f32_to_bf16_bits((rng.normal() * 0.02) as f32))
        .collect();
    let mut raw = pulse::sparse::PatchFormat::CooDownscaled.encode_indices(&idx, &layout);
    raw.extend_from_slice(pulse::util::u16_as_bytes(&vals));
    raw
}

fn main() {
    let mut b = Bench::new();
    let payload = patch_like_payload(200_000);
    println!("payload: {} bytes", payload.len());
    for codec in Codec::ALL {
        let comp = codec.compress(&payload).unwrap();
        println!("{:<8} ratio {:.2}x", codec.name(), payload.len() as f64 / comp.len() as f64);
        b.run_bytes(&format!("compress/{}", codec.name()), payload.len() as u64, || {
            std::hint::black_box(codec.compress(&payload).unwrap());
        });
        b.run_bytes(&format!("decompress/{}", codec.name()), payload.len() as u64, || {
            std::hint::black_box(codec.decompress(&comp, payload.len()).unwrap());
        });
    }
    b.write_csv(&pulse::coordinator::metrics::results_dir().join("bench_codec.csv")).unwrap();
}
