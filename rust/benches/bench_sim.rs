//! Scale-simulator benches: wall-clock cost of simulating one full
//! publish window to convergence at 1k and 10k leaves, clean and under
//! loss + churn. These price the *simulator itself* (events/sec on the
//! host), not the modeled network — the modeled numbers live in
//! `results/sim_scale.csv` from `paper scale`. Rows land in
//! `BENCH_sim.json`, so the CI bench guard catches a simulator that
//! quietly gets an order of magnitude slower and would blow the
//! sim-scale job's time budget.
//!
//! `PULSE_BENCH_FAST=1` (CI bench-smoke) skips the 10k-leaf rows.

use std::time::Duration;

use pulse::sim::churn::ChurnScript;
use pulse::sim::topo::TopoSpec;
use pulse::sim::{run, SimConfig};
use pulse::util::bench::Bench;

/// The scale-gate shape at a reduced leaf count: cap-8 tree, 5 steps
/// of 4 x 2 KiB shards on a 16 KiB anchor.
fn cfg_for(leaves: usize, seed: u64) -> SimConfig {
    let mut cfg = SimConfig::new(TopoSpec::kary(leaves, 8), seed);
    cfg.steps = 5;
    cfg.shards_per_step = 4;
    cfg.bytes_per_shard = 2048;
    cfg.anchor_bytes = 16384;
    cfg.step_interval = Duration::from_millis(20);
    cfg
}

fn faulty(leaves: usize, seed: u64) -> SimConfig {
    let mut cfg = cfg_for(leaves, seed);
    cfg.link = cfg.link.with_loss(10_000); // 1% frame loss
    cfg.churn = ChurnScript::seeded(
        seed,
        4,
        Duration::from_millis(20),
        Duration::from_millis(80),
    );
    cfg
}

fn bench_converge(b: &mut Bench, name: &str, mk: impl Fn() -> SimConfig) {
    // Simulated traffic volume is deterministic per config, so report
    // it as throughput: "modeled bytes simulated per wall second".
    let probe = run(mk());
    assert!(probe.converged, "bench config must converge: {:?}", probe);
    b.run_bytes(name, probe.link_bytes, || {
        let r = run(mk());
        assert!(r.converged);
        std::hint::black_box(&r);
    });
}

fn main() {
    let fast = std::env::var("PULSE_BENCH_FAST").ok().as_deref() == Some("1");
    let mut b = Bench::new();

    bench_converge(&mut b, "sim/converge/1k leaves clean", || cfg_for(1_000, 1));
    bench_converge(&mut b, "sim/converge/1k leaves 1pct loss + churn", || faulty(1_000, 2));
    if !fast {
        bench_converge(&mut b, "sim/converge/10k leaves clean", || cfg_for(10_000, 3));
        bench_converge(&mut b, "sim/converge/10k leaves 1pct loss + churn", || {
            faulty(10_000, 4)
        });
    }

    let results = pulse::coordinator::metrics::results_dir();
    b.write_csv(&results.join("bench_sim.csv")).unwrap();
    b.write_json(&results.join("BENCH_sim.json")).unwrap();
}
