//! End-to-end benches (§Perf): the PULSESync publish→synchronize
//! roundtrip at 1M parameters — sharded vs unsharded fan-out, over the
//! object-store AND the in-proc `SyncTransport` backends, so the
//! per-transport rows in `BENCH_e2e.json` separate protocol cost from
//! store I/O (runs everywhere, including CI bench-smoke); star vs
//! 2-level-tree relay fan-out over real TCP sockets, so the chaining
//! trade-off (one extra staging hop vs root uplink load) accumulates
//! data points per PR; the store plane (`e2e/remote_store_cold` /
//! `_warm` / `_poll_nop` — cold pull from the origin, the same pull
//! through a warm caching hop, and the NOT_MODIFIED revalidation
//! poll); a control-plane failover cycle (`e2e/control_replan`)
//! pricing detection + replan + re-subscribe + catch-up end to end;
//! and one full GRPO train step on the tiny model (requires
//! artifacts; skipped cleanly without them).
use pulse::bf16;
use pulse::coordinator;
use pulse::net::node::RelayNode;
use pulse::net::relay::Relay;
use pulse::net::transport::{
    InProcTransport, ObjectStoreTransport, RelayTransport, SyncTransport,
};
use pulse::optim::{AdamConfig, AdamW};
use pulse::pulse::sync::{Consumer, Publisher};
use pulse::rl::grpo::{self, GrpoConfig};
use pulse::rl::tasks::MathTask;
use pulse::runtime::{artifacts_dir, ModelRuntime};
use pulse::sparse::{self, container, synthetic_layout};
use pulse::storage::ObjectStore;
use pulse::util::bench::Bench;
use pulse::util::rng::Rng;

/// One publish+synchronize roundtrip bench over any transport pair:
/// the whole sync plane (diff, encode, publish, fetch, decode,
/// parallel apply, verify) per optimizer step.
fn roundtrip_over<P: SyncTransport, C: SyncTransport>(
    b: &mut Bench,
    label: &str,
    prod: P,
    cons: C,
    shards: usize,
    n: usize,
    init: &[u16],
    rng: &mut Rng,
) {
    let layout = synthetic_layout(n, 1024);
    let mut publisher = Publisher::over(prod, layout.clone(), init.to_vec(), 1_000_000)
        .unwrap()
        .with_shards(shards);
    let mut consumer = Consumer::over(cons, layout);
    consumer.synchronize().unwrap();
    let mut w = init.to_vec();
    let mut step = 0u64;
    b.run_bytes(label, (n * 2) as u64, || {
        step += 1;
        // ~1% of positions move per step (paper's sparse regime)
        for _ in 0..n / 100 {
            let i = rng.below(n as u64) as usize;
            w[i] = pulse::bf16::f32_to_bf16_bits((rng.normal() * 0.02) as f32);
        }
        publisher.publish(step, &w).unwrap();
        let cs = consumer.synchronize().unwrap();
        assert!(cs.verified);
    });
}

/// Sharded vs unsharded roundtrips, per transport backend.
fn bench_sync_roundtrip(b: &mut Bench) {
    let n = 1_000_000usize;
    let mut rng = Rng::new(11);
    let init: Vec<u16> = (0..n)
        .map(|_| pulse::bf16::f32_to_bf16_bits((rng.normal() * 0.02) as f32))
        .collect();
    for shards in [1usize, 4] {
        let store = ObjectStore::temp(&format!("bench_e2e_s{}", shards)).unwrap();
        roundtrip_over(
            b,
            &format!("e2e/pulsesync_roundtrip/1M x{} shards", shards),
            ObjectStoreTransport::new(store.clone(), "sync"),
            ObjectStoreTransport::new(store, "sync"),
            shards,
            n,
            &init,
            &mut rng,
        );
        let fabric = InProcTransport::new();
        roundtrip_over(
            b,
            &format!("e2e/pulsesync_roundtrip/1M x{} shards inproc", shards),
            fabric.clone(),
            fabric,
            shards,
            n,
            &init,
            &mut rng,
        );
    }
}

/// The observability tax: the same in-proc roundtrip with the flight
/// recorder + histograms on (default) and off. Every span on the hot
/// path is a mutex lock plus one ring store and every histogram sample
/// an atomic bump, so the two rows must stay within noise of each
/// other — `ci/bench_baseline.json` carries both so a regression in
/// either the instrumented or the bare path trips the guard.
fn bench_obs_overhead(b: &mut Bench) {
    let n = 200_000usize;
    let mut rng = Rng::new(67);
    let init: Vec<u16> = (0..n)
        .map(|_| pulse::bf16::f32_to_bf16_bits((rng.normal() * 0.02) as f32))
        .collect();
    let hub = pulse::obs::Obs::global();
    hub.set_enabled(true);
    let fabric = InProcTransport::new();
    roundtrip_over(
        b,
        "e2e/obs_recorder_on/200k x4 shards inproc",
        fabric.clone(),
        fabric,
        4,
        n,
        &init,
        &mut rng,
    );
    hub.set_enabled(false);
    let fabric = InProcTransport::new();
    roundtrip_over(
        b,
        "e2e/obs_recorder_off/200k x4 shards inproc",
        fabric.clone(),
        fabric,
        4,
        n,
        &init,
        &mut rng,
    );
    hub.set_enabled(true);
    hub.clear();
}

/// One publish → EVERY leaf synced, over a real TCP relay topology:
/// `tree = false` is the star (all leaves on the root), `tree = true`
/// a 2-level tree (two mid-tier `RelayNode`s, leaves split across
/// them, so the root fans out to 2 sockets instead of `leaves`).
/// Leaves synchronize in parallel — that is the fan-out being priced.
fn fanout_over(
    b: &mut Bench,
    label: &str,
    tree: bool,
    leaves: usize,
    n: usize,
    init: &[u16],
    rng: &mut Rng,
) {
    use pulse::util::pool;
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    let layout = synthetic_layout(n, 1024);
    let root = Arc::new(Relay::start().unwrap());
    let nodes: Vec<RelayNode> = if tree {
        (0..2).map(|_| RelayNode::join(root.port).unwrap()).collect()
    } else {
        Vec::new()
    };
    let ports: Vec<u16> = (0..leaves)
        .map(|i| if tree { nodes[i % nodes.len()].port() } else { root.port })
        .collect();
    let mut publisher = Publisher::over(
        RelayTransport::publisher(root.clone()),
        layout.clone(),
        init.to_vec(),
        1_000_000,
    )
    .unwrap()
    .with_shards(4);
    let consumers: Vec<Consumer<RelayTransport>> = ports
        .iter()
        .map(|&p| Consumer::over(RelayTransport::subscribe(p).unwrap(), layout.clone()))
        .collect();
    let sync_to = |mut c: Consumer<RelayTransport>, step: u64| {
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            if let Ok(Some(head)) = c.latest_ready() {
                if head >= step {
                    let cs = c.synchronize().unwrap();
                    assert!(cs.verified);
                    return c;
                }
            }
            assert!(Instant::now() < deadline, "step {} never became ready", step);
            std::thread::sleep(Duration::from_millis(1));
        }
    };
    // cold start every leaf off the bench clock
    let mut consumers = pool::par_map(consumers, |_, c| sync_to(c, 0));
    let mut w = init.to_vec();
    let mut step = 0u64;
    b.run_bytes(label, (n * 2) as u64, || {
        step += 1;
        for _ in 0..n / 100 {
            let i = rng.below(n as u64) as usize;
            w[i] = pulse::bf16::f32_to_bf16_bits((rng.normal() * 0.02) as f32);
        }
        publisher.publish(step, &w).unwrap();
        consumers = pool::par_map(std::mem::take(&mut consumers), |_, c| sync_to(c, step));
    });
    drop(consumers);
    for node in &nodes {
        node.stop();
    }
    root.stop();
}

/// Star vs 2-level tree for the same leaf count (bench-smoke row: the
/// perf trajectory for relay chaining).
fn bench_fanout_topologies(b: &mut Bench) {
    let n = 200_000usize;
    let leaves = 6usize;
    let mut rng = Rng::new(29);
    let init: Vec<u16> = (0..n)
        .map(|_| pulse::bf16::f32_to_bf16_bits((rng.normal() * 0.02) as f32))
        .collect();
    fanout_over(b, &format!("e2e/fanout_star/{}leaves 200k", leaves), false, leaves, n, &init, &mut rng);
    fanout_over(b, &format!("e2e/fanout_tree2/{}leaves 200k", leaves), true, leaves, n, &init, &mut rng);
}

/// The store plane priced three ways (bench-smoke rows for the patch
/// CDN): a cold consumer pulling the whole stream straight from the
/// origin store server; the same cold pull through an already-warm
/// caching hop (origin never touched for data objects); and the no-op
/// poll — a conditional GET of the head ready marker revalidated
/// through the hop, answered NOT_MODIFIED end to end.
fn bench_remote_store(b: &mut Bench) {
    use pulse::net::store::{
        caching_hop, DirectStore, GetOutcome, ObjectApi, RemoteStoreTransport, StoreClient,
        StoreServer,
    };
    use pulse::net::transport::delta_ready_key;
    use pulse::storage::retention::RetentionPolicy;
    use std::sync::Arc;

    let n = 200_000usize;
    let steps = 3u64;
    let layout = synthetic_layout(n, 1024);
    let mut rng = Rng::new(53);
    let init: Vec<u16> = (0..n)
        .map(|_| pulse::bf16::f32_to_bf16_bits((rng.normal() * 0.02) as f32))
        .collect();
    let store = ObjectStore::temp("bench_e2e_store").unwrap();
    let origin = StoreServer::serve(Arc::new(DirectStore::new(store.clone())), None).unwrap();
    // publish the stream once; every bench iteration replays a cold sync
    let mut publisher = Publisher::over(
        RemoteStoreTransport::connect(origin.port(), "sync"),
        layout.clone(),
        init.clone(),
        50,
    )
    .unwrap()
    .with_shards(4);
    let mut w = init;
    for step in 1..=steps {
        for _ in 0..n / 100 {
            let i = rng.below(n as u64) as usize;
            w[i] = pulse::bf16::f32_to_bf16_bits((rng.normal() * 0.02) as f32);
        }
        publisher.publish(step, &w).unwrap();
    }

    b.run_bytes("e2e/remote_store_cold/200k x4 shards", (n * 2) as u64, || {
        let mut c =
            Consumer::over(RemoteStoreTransport::connect(origin.port(), "sync"), layout.clone());
        let cs = c.synchronize().unwrap();
        assert!(cs.verified);
        assert_eq!(c.step, steps);
    });

    let (hop, _cache) = caching_hop(origin.port(), RetentionPolicy::default(), None).unwrap();
    b.run_bytes("e2e/remote_store_warm/200k x4 shards hop", (n * 2) as u64, || {
        let mut c =
            Consumer::over(RemoteStoreTransport::connect(hop.port(), "sync"), layout.clone());
        let cs = c.synchronize().unwrap();
        assert!(cs.verified);
        assert_eq!(c.step, steps);
    });

    // the steady-state poll: revalidate the head marker through the hop
    let client = StoreClient::new(hop.port());
    let marker = format!("sync/{}", delta_ready_key(steps));
    let etag = match client.get(&marker, None, None).unwrap() {
        GetOutcome::Body { etag, .. } => etag,
        other => panic!("head marker must have a body, got {:?}", other),
    };
    b.run("e2e/remote_store_poll_nop/cond GET", || {
        match client.get(&marker, None, Some(etag.as_str())).unwrap() {
            GetOutcome::NotModified { .. } => {}
            other => panic!("expected NOT_MODIFIED, got {:?}", other),
        }
    });

    hop.stop();
    origin.stop();
    std::fs::remove_dir_all(store.root()).unwrap();
}

/// One full control-plane failover cycle: assemble a plane-managed
/// tree (1 active relay + 1 standby, 2 leaves) from JOINs, stream,
/// crash the active relay silently, and wait until every leaf has
/// verified a step published after the kill. The row tracks
/// end-to-end re-parenting latency (detection + replan + re-subscribe
/// + catch-up) per PR in `BENCH_e2e.json`.
fn bench_control_replan(b: &mut Bench) {
    use pulse::net::control::{
        ControlConfig, ControlPlane, ControlSubscriberTransport, ControlledNode,
    };
    use pulse::net::relay::{DEFAULT_QUEUE_DEPTH, INDEX_STEPS};
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    let n = 50_000usize;
    let layout = synthetic_layout(n, 1024);
    let mut rng = Rng::new(83);
    let init: Vec<u16> = (0..n)
        .map(|_| pulse::bf16::f32_to_bf16_bits((rng.normal() * 0.02) as f32))
        .collect();
    let hb = Duration::from_millis(30);
    let cfg = ControlConfig {
        fanout_cap: 2,
        min_relay_levels: 1,
        heartbeat_interval: hb,
        missed_heartbeats: 5, // 150 ms death timeout
        ..Default::default()
    };
    let wait_sync = |c: &mut Consumer<ControlSubscriberTransport>, step: u64| {
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            if let Ok(Some(head)) = c.latest_ready() {
                if head >= step {
                    if let Ok(cs) = c.synchronize() {
                        assert!(cs.verified);
                        return;
                    }
                }
            }
            assert!(Instant::now() < deadline, "step {} never synced", step);
            std::thread::sleep(Duration::from_millis(2));
        }
    };
    b.run("e2e/control_replan/2leaves 50k", || {
        let root = Arc::new(Relay::start().unwrap());
        let mut publisher = Publisher::over(
            RelayTransport::publisher(root.clone()),
            layout.clone(),
            init.clone(),
            1_000,
        )
        .unwrap()
        .with_shards(2);
        let plane = ControlPlane::start(root.port, cfg).unwrap();
        let nodes: Vec<ControlledNode> = (0..2)
            .map(|_| {
                ControlledNode::join_with_opts(plane.port, DEFAULT_QUEUE_DEPTH, INDEX_STEPS, hb)
                    .unwrap()
            })
            .collect();
        let mut leaves: Vec<Consumer<ControlSubscriberTransport>> = (0..2)
            .map(|_| {
                Consumer::over(
                    ControlSubscriberTransport::join_with_heartbeat(plane.port, hb).unwrap(),
                    layout.clone(),
                )
            })
            .collect();
        let deadline = Instant::now() + Duration::from_secs(20);
        while plane.live_peers() != (2, 2) {
            assert!(Instant::now() < deadline, "membership never settled");
            std::thread::sleep(Duration::from_millis(2));
        }
        let mut w = init.clone();
        for i in 0..n / 200 {
            w[(i * 199) % n] = pulse::bf16::f32_to_bf16_bits(0.03);
        }
        publisher.publish(1, &w).unwrap();
        for leaf in leaves.iter_mut() {
            wait_sync(leaf, 1);
        }
        // crash whichever relay is active (the one that attached)
        let victim = nodes
            .iter()
            .find(|nd| nd.node().upstream_attached())
            .expect("one relay must be active");
        victim.fail_silently();
        for i in 0..n / 200 {
            w[(i * 211) % n] = pulse::bf16::f32_to_bf16_bits(-0.03);
        }
        publisher.publish(2, &w).unwrap();
        // the measured quantity: both leaves verified at the post-kill
        // step, which requires detection + replan + re-subscribe
        for leaf in leaves.iter_mut() {
            wait_sync(leaf, 2);
        }
        drop(leaves);
        for nd in &nodes {
            nd.stop();
        }
        plane.stop();
        root.stop();
    });
}

/// One full GRPO step (rollout + reward + advantages + grad + AdamW +
/// sparsity meter + PULSESync encode) on the tiny model.
fn bench_train_step(b: &mut Bench) {
    let rt = match ModelRuntime::load(&artifacts_dir(), "tiny", &["rollout", "grad"]) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping e2e train-step bench (run `make artifacts`): {e:#}");
            return;
        }
    };
    let task = MathTask::default();
    let cfg = GrpoConfig::default();
    let mut master = coordinator::init_master(&rt, 0).unwrap();
    let mut opt = AdamW::new(master.len(), AdamConfig::default());
    let mut rng = Rng::new(0);
    let mut prev = Vec::new();
    bf16::cast_slice_par(&master, &mut prev);
    b.run("e2e/full_train_step/tiny", || {
        let policy: Vec<f32> =
            master.iter().map(|&x| pulse::bf16::bf16_round(x)).collect();
        let batch = grpo::generate_batch(&rt, &policy, &task, cfg, &mut rng).unwrap();
        let out = rt
            .grad(&master, &batch.tokens, &batch.advantages, &batch.old_logprobs, &batch.mask)
            .unwrap();
        opt.step(&mut master, &out.grads);
        // PULSESync encode of the new view
        let mut view = Vec::new();
        bf16::cast_slice_par(&master, &mut view);
        let (idx, vals) = sparse::diff_gather_bf16(&prev, &view);
        let patch = container::Patch {
            step: 1,
            base_step: 0,
            total_params: view.len() as u64,
            indices: idx,
            values: container::Values::Bf16(vals),
            result_hash: String::new(),
            chunk_elems: 0,
            ..Default::default()
        };
        let obj =
            container::encode(&patch, &rt.manifest.layout, Default::default()).unwrap();
        prev = view;
        std::hint::black_box(obj);
    });
}

fn main() {
    let mut b = Bench::new();
    bench_sync_roundtrip(&mut b);
    bench_obs_overhead(&mut b);
    bench_fanout_topologies(&mut b);
    bench_remote_store(&mut b);
    bench_control_replan(&mut b);
    bench_train_step(&mut b);
    let results = pulse::coordinator::metrics::results_dir();
    b.write_csv(&results.join("bench_e2e.csv")).unwrap();
    b.write_json(&results.join("BENCH_e2e.json")).unwrap();
}
