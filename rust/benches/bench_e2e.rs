//! End-to-end benches (§Perf): the PULSESync publish→synchronize
//! roundtrip over the object store at 1M parameters (sharded vs
//! unsharded fan-out — runs everywhere, including CI bench-smoke), and
//! one full GRPO train step on the tiny model (requires artifacts;
//! skipped cleanly without them).
use pulse::bf16;
use pulse::coordinator;
use pulse::optim::{AdamConfig, AdamW};
use pulse::pulse::sync::{Consumer, Publisher};
use pulse::rl::grpo::{self, GrpoConfig};
use pulse::rl::tasks::MathTask;
use pulse::runtime::{artifacts_dir, ModelRuntime};
use pulse::sparse::{self, container, synthetic_layout};
use pulse::storage::ObjectStore;
use pulse::util::bench::Bench;
use pulse::util::rng::Rng;

/// Sharded vs unsharded publish+synchronize over a temp store: the
/// whole sync plane (diff, encode, upload, download, decode, parallel
/// apply, verify) per optimizer step.
fn bench_sync_roundtrip(b: &mut Bench) {
    let n = 1_000_000usize;
    let layout = synthetic_layout(n, 1024);
    let mut rng = Rng::new(11);
    let init: Vec<u16> = (0..n)
        .map(|_| pulse::bf16::f32_to_bf16_bits((rng.normal() * 0.02) as f32))
        .collect();
    for shards in [1usize, 4] {
        let store = ObjectStore::temp(&format!("bench_e2e_s{}", shards)).unwrap();
        let mut publisher =
            Publisher::new(store.clone(), "sync", layout.clone(), init.clone(), 1_000_000)
                .unwrap()
                .with_shards(shards);
        let mut consumer = Consumer::new(store, "sync", layout.clone());
        consumer.synchronize().unwrap();
        let mut w = init.clone();
        let mut step = 0u64;
        b.run_bytes(
            &format!("e2e/pulsesync_roundtrip/1M x{} shards", shards),
            (n * 2) as u64,
            || {
                step += 1;
                // ~1% of positions move per step (paper's sparse regime)
                for _ in 0..n / 100 {
                    let i = rng.below(n as u64) as usize;
                    w[i] = pulse::bf16::f32_to_bf16_bits((rng.normal() * 0.02) as f32);
                }
                publisher.publish(step, &w).unwrap();
                let cs = consumer.synchronize().unwrap();
                assert!(cs.verified);
            },
        );
    }
}

/// One full GRPO step (rollout + reward + advantages + grad + AdamW +
/// sparsity meter + PULSESync encode) on the tiny model.
fn bench_train_step(b: &mut Bench) {
    let rt = match ModelRuntime::load(&artifacts_dir(), "tiny", &["rollout", "grad"]) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping e2e train-step bench (run `make artifacts`): {e:#}");
            return;
        }
    };
    let task = MathTask::default();
    let cfg = GrpoConfig::default();
    let mut master = coordinator::init_master(&rt, 0).unwrap();
    let mut opt = AdamW::new(master.len(), AdamConfig::default());
    let mut rng = Rng::new(0);
    let mut prev = Vec::new();
    bf16::cast_slice_par(&master, &mut prev);
    b.run("e2e/full_train_step/tiny", || {
        let policy: Vec<f32> =
            master.iter().map(|&x| pulse::bf16::bf16_round(x)).collect();
        let batch = grpo::generate_batch(&rt, &policy, &task, cfg, &mut rng).unwrap();
        let out = rt
            .grad(&master, &batch.tokens, &batch.advantages, &batch.old_logprobs, &batch.mask)
            .unwrap();
        opt.step(&mut master, &out.grads);
        // PULSESync encode of the new view
        let mut view = Vec::new();
        bf16::cast_slice_par(&master, &mut view);
        let (idx, vals) = sparse::diff_gather_bf16(&prev, &view);
        let patch = container::Patch {
            step: 1,
            base_step: 0,
            total_params: view.len() as u64,
            indices: idx,
            values: container::Values::Bf16(vals),
            result_hash: String::new(),
            chunk_elems: 0,
            ..Default::default()
        };
        let obj =
            container::encode(&patch, &rt.manifest.layout, Default::default()).unwrap();
        prev = view;
        std::hint::black_box(obj);
    });
}

fn main() {
    let mut b = Bench::new();
    bench_sync_roundtrip(&mut b);
    bench_train_step(&mut b);
    let results = pulse::coordinator::metrics::results_dir();
    b.write_csv(&results.join("bench_e2e.csv")).unwrap();
    b.write_json(&results.join("BENCH_e2e.json")).unwrap();
}
