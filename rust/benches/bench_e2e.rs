//! End-to-end train-step bench (§Perf): one full GRPO step (rollout +
//! reward + advantages + grad + AdamW + sparsity meter + PULSESync
//! encode) on the tiny model. Requires artifacts.
use pulse::coordinator;
use pulse::optim::{AdamConfig, AdamW};
use pulse::rl::grpo::{self, GrpoConfig};
use pulse::rl::tasks::MathTask;
use pulse::runtime::{artifacts_dir, ModelRuntime};
use pulse::sparse::{self, container};
use pulse::util::bench::Bench;
use pulse::util::rng::Rng;

fn main() {
    let rt = match ModelRuntime::load(&artifacts_dir(), "tiny", &["rollout", "grad"]) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping bench_e2e (run `make artifacts`): {e:#}");
            return;
        }
    };
    let task = MathTask::default();
    let cfg = GrpoConfig::default();
    let mut master = coordinator::init_master(&rt, 0).unwrap();
    let mut opt = AdamW::new(master.len(), AdamConfig::default());
    let mut rng = Rng::new(0);
    let mut prev = Vec::new();
    pulse::bf16::cast_slice_par(&master, &mut prev);
    let mut b = Bench::new();
    b.run("e2e/full_train_step/tiny", || {
        let policy: Vec<f32> =
            master.iter().map(|&x| pulse::bf16::bf16_round(x)).collect();
        let batch = grpo::generate_batch(&rt, &policy, &task, cfg, &mut rng).unwrap();
        let out = rt
            .grad(&master, &batch.tokens, &batch.advantages, &batch.old_logprobs, &batch.mask)
            .unwrap();
        opt.step(&mut master, &out.grads);
        // PULSESync encode of the new view
        let mut view = Vec::new();
        pulse::bf16::cast_slice_par(&master, &mut view);
        let (idx, vals) = sparse::diff_gather_bf16(&prev, &view);
        let patch = container::Patch {
            step: 1,
            base_step: 0,
            total_params: view.len() as u64,
            indices: idx,
            values: container::Values::Bf16(vals),
            result_hash: String::new(),
            chunk_elems: 0,
        };
        let obj =
            container::encode(&patch, &rt.manifest.layout, Default::default()).unwrap();
        prev = view;
        std::hint::black_box(obj);
    });
    b.write_csv(&pulse::coordinator::metrics::results_dir().join("bench_e2e.csv")).unwrap();
}
