//! PULSESync patch pipeline micro-bench: diff, gather, encode, decode,
//! apply, verify — the trainer/worker hot path (§Perf L3).
//!
//! The `diff_scalar` / `sha256` rows are the pre-hash-tree baselines;
//! `diff_word`, `hashtree_build` and `hashtree_incremental` are the
//! O(nnz)-hot-path replacements, so the speedup is recorded side by
//! side in `bench_patch.csv`.
use pulse::pulse::sync::ShardedEncoder;
use pulse::sparse::container::EncodeOpts;
use pulse::sparse::hashtree::{self, HashTree, ShardPatchRef, DEFAULT_CHUNK_ELEMS};
use pulse::sparse::{self, container, PatchFormat};
use pulse::util::bench::Bench;
use pulse::util::rng::Rng;

fn main() {
    let n = 4_000_000usize;
    let layout = sparse::synthetic_layout(n, 1024);
    let mut rng = Rng::new(7);
    let old: Vec<u16> = (0..n)
        .map(|_| pulse::bf16::f32_to_bf16_bits((rng.normal() * 0.02) as f32))
        .collect();
    let mut new = old.clone();
    for _ in 0..n / 100 {
        let i = rng.below(n as u64) as usize;
        new[i] = pulse::bf16::f32_to_bf16_bits((rng.normal() * 0.02) as f32);
    }
    let mut b = Bench::new();
    let bytes = (n * 2) as u64;
    // baseline: the old element-at-a-time diff loop
    b.run_bytes("diff_scalar/4M (1% changed)", bytes, || {
        let parts = pulse::util::pool::par_ranges(n, 1 << 16, |r| {
            let mut v = Vec::new();
            for i in r {
                if old[i] != new[i] {
                    v.push(i as u64);
                }
            }
            v
        });
        std::hint::black_box(parts);
    });
    b.run_bytes("diff_word/4M (1% changed)", bytes, || {
        std::hint::black_box(sparse::diff_bf16(&old, &new));
    });
    b.run_bytes("diff_gather_fused/4M (1% changed)", bytes, || {
        std::hint::black_box(sparse::diff_gather_bf16(&old, &new));
    });
    let (idx, vals) = sparse::diff_gather_bf16(&old, &new);
    println!("nnz = {}", idx.len());
    for fmt in [PatchFormat::CooDownscaled, PatchFormat::FlatVarint] {
        b.run(&format!("encode_indices/{}", fmt.name()), || {
            std::hint::black_box(fmt.encode_indices(&idx, &layout));
        });
    }
    let tree = HashTree::build(&new, DEFAULT_CHUNK_ELEMS);
    let patch = container::Patch {
        step: 1,
        base_step: 0,
        total_params: n as u64,
        indices: idx.clone(),
        values: container::Values::Bf16(vals.clone()),
        result_hash: tree.root_hex(),
        chunk_elems: tree.chunk_elems() as u64,
        ..Default::default()
    };
    b.run_bytes("container_encode/zstd1", bytes, || {
        std::hint::black_box(container::encode(&patch, &layout, Default::default()).unwrap());
    });
    let obj = container::encode(&patch, &layout, Default::default()).unwrap();
    println!("container: {} bytes ({:.0}x vs full)", obj.len(), bytes as f64 / obj.len() as f64);
    b.run_bytes("container_decode/zstd1", bytes, || {
        std::hint::black_box(container::decode(&obj, &layout).unwrap());
    });
    let mut target = old.clone();
    b.run("apply_patch/40k values", || {
        sparse::apply_u16(&mut target, &idx, &vals);
        std::hint::black_box(&target);
    });
    // verify cost: old full-buffer scalar SHA-256 ...
    b.run_bytes("sha256/8MB ckpt", bytes, || {
        std::hint::black_box(pulse::util::sha256_hex(pulse::util::u16_as_bytes(&old)));
    });
    // ... vs chunked hash tree: parallel from-scratch build (slow path /
    // anchor verify) and incremental per-patch update (steady state)
    b.run_bytes("hashtree_build/4M", bytes, || {
        std::hint::black_box(HashTree::build(&old, DEFAULT_CHUNK_ELEMS));
    });
    let mut inc = HashTree::build(&old, DEFAULT_CHUNK_ELEMS);
    b.run_bytes("hashtree_incremental/1% changed", bytes, || {
        inc.update(&new, &idx);
        std::hint::black_box(inc.root());
    });
    let mut fused_w = old.clone();
    let mut fused = HashTree::build(&fused_w, DEFAULT_CHUNK_ELEMS);
    b.run("apply_and_rehash/40k values", || {
        fused.apply_and_rehash(&mut fused_w, &idx, &vals);
        std::hint::black_box(fused.root());
    });

    // sharded fan-out: the whole publisher front half (per-shard
    // diff+gather, one tree update, per-shard encode+compress) on the
    // pool, alternating old↔new so every iteration does real work.
    // The `balanced` row adds the per-chunk nnz profile + equal-nnz
    // cut on top, so the cost of load-balancing is visible next to the
    // static split.
    for (shards, balance) in [(1usize, false), (4, false), (4, true), (8, false)] {
        let mut enc = ShardedEncoder::new(old.clone(), 0);
        enc.balance = balance;
        let label = if balance {
            format!("shard_encode_step/{} shards balanced", shards)
        } else {
            format!("shard_encode_step/{} shards", shards)
        };
        let mut step = 0u64;
        let mut to_new = true;
        b.run_bytes(&label, bytes, || {
            step += 1;
            let target: &[u16] = if to_new { &new } else { &old };
            to_new = !to_new;
            std::hint::black_box(
                enc.encode_step(step, target, &layout, EncodeOpts::default(), shards)
                    .unwrap(),
            );
        });
    }

    // consumer-side parallel sharded apply+verify, alternating
    // directions with precomputed per-shard slices and subtree roots
    let shard_n = 4usize;
    let ranges = hashtree::shard_ranges(n, DEFAULT_CHUNK_ELEMS, shard_n);
    let vals_back: Vec<u16> = idx.iter().map(|&i| old[i as usize]).collect();
    let tree_old = HashTree::build(&old, DEFAULT_CHUNK_ELEMS);
    let cuts: Vec<(usize, usize)> = ranges
        .iter()
        .map(|r| {
            (
                idx.partition_point(|&i| (i as usize) < r.start),
                idx.partition_point(|&i| (i as usize) < r.end),
            )
        })
        .collect();
    let roots_new: Vec<String> =
        ranges.iter().map(|r| tree.subtree_root_hex(r.start, r.end)).collect();
    let roots_old: Vec<String> =
        ranges.iter().map(|r| tree_old.subtree_root_hex(r.start, r.end)).collect();
    let mut sw = old.clone();
    let mut st = HashTree::build(&sw, DEFAULT_CHUNK_ELEMS);
    let mut to_new = true;
    b.run(&format!("apply_and_rehash_shards/{} shards", shard_n), || {
        let (values, roots) =
            if to_new { (&vals, &roots_new) } else { (&vals_back, &roots_old) };
        to_new = !to_new;
        let refs: Vec<ShardPatchRef> = ranges
            .iter()
            .zip(&cuts)
            .zip(roots.iter())
            .map(|((r, &(a, b_)), root)| ShardPatchRef {
                elem_lo: r.start,
                elem_hi: r.end,
                indices: &idx[a..b_],
                values: &values[a..b_],
                expect_root: root,
            })
            .collect();
        let ok = st.apply_and_rehash_shards(&mut sw, &refs);
        assert!(ok.iter().all(|&v| v));
        std::hint::black_box(st.root());
    });

    let results = pulse::coordinator::metrics::results_dir();
    b.write_csv(&results.join("bench_patch.csv")).unwrap();
    b.write_json(&results.join("BENCH_patch.json")).unwrap();
}
