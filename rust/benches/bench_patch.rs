//! PULSESync patch pipeline micro-bench: diff, gather, encode, decode,
//! apply — the trainer/worker hot path (§Perf L3).
use pulse::sparse::{self, container, PatchFormat};
use pulse::util::bench::Bench;
use pulse::util::rng::Rng;

fn main() {
    let n = 4_000_000usize;
    let layout = sparse::synthetic_layout(n, 1024);
    let mut rng = Rng::new(7);
    let old: Vec<u16> = (0..n)
        .map(|_| pulse::bf16::f32_to_bf16_bits((rng.normal() * 0.02) as f32))
        .collect();
    let mut new = old.clone();
    for _ in 0..n / 100 {
        let i = rng.below(n as u64) as usize;
        new[i] = pulse::bf16::f32_to_bf16_bits((rng.normal() * 0.02) as f32);
    }
    let mut b = Bench::new();
    let bytes = (n * 2) as u64;
    b.run_bytes("diff_bf16/4M (1% changed)", bytes, || {
        std::hint::black_box(sparse::diff_bf16(&old, &new));
    });
    let idx = sparse::diff_bf16(&old, &new);
    let vals = sparse::gather_u16(&new, &idx);
    println!("nnz = {}", idx.len());
    for fmt in [PatchFormat::CooDownscaled, PatchFormat::FlatVarint] {
        b.run(&format!("encode_indices/{}", fmt.name()), || {
            std::hint::black_box(fmt.encode_indices(&idx, &layout));
        });
    }
    let patch = container::Patch {
        step: 1,
        base_step: 0,
        total_params: n as u64,
        indices: idx.clone(),
        values: container::Values::Bf16(vals.clone()),
        result_hash: pulse::util::sha256_hex(pulse::util::u16_as_bytes(&new)),
    };
    b.run_bytes("container_encode/zstd1", bytes, || {
        std::hint::black_box(container::encode(&patch, &layout, Default::default()).unwrap());
    });
    let obj = container::encode(&patch, &layout, Default::default()).unwrap();
    println!("container: {} bytes ({:.0}x vs full)", obj.len(), bytes as f64 / obj.len() as f64);
    b.run_bytes("container_decode/zstd1", bytes, || {
        std::hint::black_box(container::decode(&obj, &layout).unwrap());
    });
    let mut target = old.clone();
    b.run("apply_patch/40k values", || {
        sparse::apply_u16(&mut target, &idx, &vals);
        std::hint::black_box(&target);
    });
    b.run_bytes("sha256/8MB ckpt", bytes, || {
        std::hint::black_box(pulse::util::sha256_hex(pulse::util::u16_as_bytes(&old)));
    });
    b.write_csv(&pulse::coordinator::metrics::results_dir().join("bench_patch.csv")).unwrap();
}
