//! Optimizer micro-bench (§Perf L3): fused AdamW and the Nesterov outer
//! step over large flat vectors.
use pulse::optim::{AdamConfig, AdamW, Nesterov};
use pulse::util::bench::Bench;
use pulse::util::rng::Rng;

fn main() {
    let n = 8_000_000usize;
    let mut rng = Rng::new(4);
    let mut params: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.02) as f32).collect();
    let grads: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.1) as f32).collect();
    let bytes = (n * 4) as u64;
    let mut b = Bench::new();
    let mut opt = AdamW::new(n, AdamConfig::default());
    b.run_bytes("adamw/step/8M", bytes, || {
        std::hint::black_box(opt.step(&mut params, &grads));
    });
    let mut opt_noclip =
        AdamW::new(n, AdamConfig { clip_global_norm: 0.0, ..Default::default() });
    b.run_bytes("adamw/step_noclip/8M", bytes, || {
        std::hint::black_box(opt_noclip.step(&mut params, &grads));
    });
    let mut outer = Nesterov::new(n);
    b.run_bytes("nesterov/step/8M", bytes, || {
        outer.step(&mut params, &grads);
        std::hint::black_box(&params);
    });
    let mut view = Vec::new();
    b.run_bytes("bf16_cast/8M", bytes, || {
        pulse::bf16::cast_slice_par(&params, &mut view);
        std::hint::black_box(&view);
    });
    b.write_csv(&pulse::coordinator::metrics::results_dir().join("bench_optim.csv")).unwrap();
}
