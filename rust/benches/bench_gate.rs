//! Compute-visibility gate micro-bench (§Perf L3): the native gate vs
//! the error-feedback round, per dtype.
use pulse::bf16::Dtype;
use pulse::gate;
use pulse::util::bench::Bench;
use pulse::util::rng::Rng;

fn main() {
    let n = 8_000_000usize;
    let mut rng = Rng::new(3);
    let theta: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.02) as f32).collect();
    let s: Vec<f32> = (0..n).map(|_| (rng.normal() * 3e-6) as f32).collect();
    let bytes = (n * 4) as u64;
    let mut b = Bench::new();
    for d in [Dtype::Bf16, Dtype::Fp8E4M3, Dtype::Mxfp4] {
        b.run_bytes(&format!("gate/{}/8M", d.name()), bytes, || {
            std::hint::black_box(gate::gate(d, &theta, &s));
        });
    }
    b.run_bytes("gate/count_only/8M", bytes, || {
        std::hint::black_box(gate::count_visible_bf16(&theta, &s));
    });
    let mut ef = gate::feedback::ErrorFeedback::new(n, Dtype::Bf16);
    b.run_bytes("error_feedback/round/8M", bytes, || {
        std::hint::black_box(ef.gate_and_update(&theta, &s));
    });
    b.write_csv(&pulse::coordinator::metrics::results_dir().join("bench_gate.csv")).unwrap();
}
