//! PJRT step latency (§Perf L2/L3 boundary): rollout / grad / score on
//! the tiny model, including literal marshalling. Requires artifacts.
use pulse::runtime::{artifacts_dir, ModelRuntime};
use pulse::util::bench::Bench;

fn main() {
    let rt = match ModelRuntime::load(&artifacts_dir(), "tiny", &["rollout", "grad", "score"]) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping bench_runtime (run `make artifacts`): {e:#}");
            return;
        }
    };
    let d = rt.manifest.dims.clone();
    let flat = rt.load_init(&artifacts_dir()).unwrap();
    let tokens: Vec<i32> = (0..d.batch * d.seq).map(|i| (i % d.vocab) as i32).collect();
    let prompts: Vec<i32> =
        (0..d.batch * d.prompt_len).map(|i| (i % d.vocab) as i32).collect();
    let mut b = Bench::new();
    b.run("runtime/score/tiny", || {
        std::hint::black_box(rt.score(&flat, &tokens).unwrap());
    });
    b.run("runtime/rollout/tiny (8 gen steps)", || {
        std::hint::black_box(rt.rollout(&flat, &prompts, [1, 2], 1.0).unwrap());
    });
    let (old_lp, _) = rt.score(&flat, &tokens).unwrap();
    let adv: Vec<f32> = (0..d.batch).map(|i| if i % 2 == 0 { 0.5 } else { -0.5 }).collect();
    let mask = vec![1.0f32; d.batch * d.gen_len];
    b.run("runtime/grad/tiny", || {
        std::hint::black_box(rt.grad(&flat, &tokens, &adv, &old_lp, &mask).unwrap());
    });
    b.write_csv(&pulse::coordinator::metrics::results_dir().join("bench_runtime.csv")).unwrap();
}
