"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle in
ref.py, swept over shapes/dtypes with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import adam as adam_k
from compile.kernels import attention as attn_k
from compile.kernels import gate as gate_k
from compile.kernels import ref

SETTINGS = dict(max_examples=20, deadline=None)


def rand(key, shape, dtype, scale=1.0):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


# ---------------------------------------------------------------- attention
@settings(**SETTINGS)
@given(
    b=st.integers(1, 4),
    h=st.integers(1, 4),
    t=st.sampled_from([1, 2, 3, 8, 17, 24, 33]),
    hd=st.sampled_from([4, 8, 16, 32]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(b, h, t, hd, dtype, seed):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = rand(kq, (b, h, t, hd), dtype)
    k = rand(kk, (b, h, t, hd), dtype)
    v = rand(kv, (b, h, t, hd), dtype)
    got = attn_k.attention(q, k, v)
    want = ref.attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)


def test_attention_is_causal():
    # Changing future K/V must not change past outputs.
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = rand(kq, (1, 2, 16, 8), jnp.float32)
    k = rand(kk, (1, 2, 16, 8), jnp.float32)
    v = rand(kv, (1, 2, 16, 8), jnp.float32)
    o1 = attn_k.attention(q, k, v)
    k2 = k.at[:, :, 10:, :].set(99.0)
    v2 = v.at[:, :, 10:, :].set(-99.0)
    o2 = attn_k.attention(q, k2, v2)
    np.testing.assert_allclose(
        np.asarray(o1[:, :, :10]), np.asarray(o2[:, :, :10]), rtol=1e-6)
    assert not np.allclose(np.asarray(o1[:, :, 10:]), np.asarray(o2[:, :, 10:]))


@settings(**SETTINGS)
@given(
    b=st.integers(1, 2),
    t=st.sampled_from([4, 9, 16]),
    hd=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_gradients_match_ref(b, t, hd, seed):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = rand(kq, (b, 2, t, hd), jnp.float32)
    k = rand(kk, (b, 2, t, hd), jnp.float32)
    v = rand(kv, (b, 2, t, hd), jnp.float32)

    def loss_kernel(q, k, v):
        return jnp.sum(jnp.sin(attn_k.attention(q, k, v)))

    def loss_ref(q, k, v):
        return jnp.sum(jnp.sin(ref.attention_ref(q, k, v)))

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-3,
                                   atol=1e-4)


# --------------------------------------------------------------------- adam
@settings(**SETTINGS)
@given(
    n=st.sampled_from([1, 7, 1000, 1 << 14, (1 << 14) + 3, 100_000]),
    t=st.integers(1, 50),
    wd=st.sampled_from([0.0, 0.1]),
    seed=st.integers(0, 2**31 - 1),
)
def test_adam_kernel_matches_ref(n, t, wd, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    p = rand(ks[0], (n,), jnp.float32, 0.02)
    m = rand(ks[1], (n,), jnp.float32, 1e-3)
    v = jnp.abs(rand(ks[2], (n,), jnp.float32, 1e-6))
    g = rand(ks[3], (n,), jnp.float32, 0.1)
    lr, b1, b2, eps = 3e-6, 0.9, 0.999, 1e-8
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t
    got = adam_k.adamw_step(p, m, v, g, jnp.float32(lr), jnp.float32(bc1),
                            jnp.float32(bc2), weight_decay=wd)
    want = ref.adamw_ref(p, m, v, g, lr, b1, b2, eps, wd, bc1, bc2)
    for a, b_ in zip(got, want):
        # fusion/FMA ordering differs between the pallas-interpret and
        # jnp paths; allow a few ULPs.
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=2e-5,
                                   atol=1e-9)


# --------------------------------------------------------------------- gate
@settings(**SETTINGS)
@given(
    n=st.sampled_from([1, 63, 1 << 15, (1 << 15) + 11, 200_000]),
    scale=st.sampled_from([1e-8, 1e-6, 1e-4, 1e-2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gate_kernel_matches_ref(n, scale, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    theta = rand(k1, (n,), jnp.float32, 0.02)
    s = rand(k2, (n,), jnp.float32, scale)
    got = gate_k.visibility_gate(theta, s)
    want = ref.gate_ref(theta, s)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_gate_zero_update_invisible():
    theta = jnp.full((1000,), 0.5, jnp.float32)
    assert int(gate_k.visibility_gate(theta, jnp.zeros_like(theta)).sum()) == 0


def test_gate_sparsity_tracks_learning_rate():
    """Fig. 15 in miniature: larger updates → lower sparsity."""
    key = jax.random.PRNGKey(1)
    theta = 0.02 * jax.random.normal(key, (50_000,), jnp.float32)
    sign = jnp.sign(jax.random.normal(jax.random.PRNGKey(2), theta.shape))
    sp = []
    for eta in [3e-7, 3e-6, 3e-5, 3e-4]:
        mask = gate_k.visibility_gate(theta, sign * eta)
        sp.append(1.0 - float(mask.mean()))
    assert sp[0] > sp[1] > sp[2] > sp[3]
    assert sp[0] > 0.95 and sp[3] < 0.35, sp
