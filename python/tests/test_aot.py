"""AOT pipeline tests: lowering emits parseable HLO text with the right
parameter signature, and the manifest is consistent with the model."""

import json
import os
import tempfile

import numpy as np
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    meta = aot.lower_size(M.SIZES["tiny"], out, skip_existing=False)
    return out, meta


def test_all_artifacts_written(built):
    out, meta = built
    for kind, fname in meta["artifacts"].items():
        path = os.path.join(out, fname)
        assert os.path.exists(path), kind
        text = open(path).read()
        assert text.startswith("HloModule"), f"{kind} is not HLO text"
        assert "ENTRY" in text


def test_hlo_signature_matches_manifest(built):
    out, meta = built
    n = meta["n_params"]
    grad = open(os.path.join(out, meta["artifacts"]["grad"])).read()
    # flat param vector appears as an f32[N] parameter
    assert f"f32[{n}]" in grad, "flat param parameter missing"
    dims = meta["dims"]
    b, t = dims["batch"], dims["seq"]
    assert f"s32[{b},{t}]" in grad, "token parameter missing"


def test_manifest_tensor_layout(built):
    _, meta = built
    off = 0
    for t in meta["tensors"]:
        assert t["offset"] == off
        assert t["len"] == int(np.prod(t["shape"]))
        off += t["len"]
    assert off == meta["n_params"]


def test_init_bin_roundtrip(built):
    out, meta = built
    flat = np.fromfile(os.path.join(out, meta["init"]), dtype=np.float32)
    assert flat.shape[0] == meta["n_params"]
    # oracle agrees with a fresh in-process evaluation
    import jax.numpy as jnp
    cfg = M.SIZES["tiny"]
    toks = (np.arange(cfg.batch * cfg.seq, dtype=np.int32)
            .reshape(cfg.batch, cfg.seq) % cfg.vocab)
    lp, _ = M.score(cfg, jnp.asarray(flat), jnp.asarray(toks))
    got = float(np.asarray(lp, np.float64).sum())
    want = meta["oracle"]["logprob_sum"]
    assert abs(got - want) < 1e-3 * max(1.0, abs(want))


def test_skip_existing_is_idempotent(built):
    out, meta = built
    grad_path = os.path.join(out, meta["artifacts"]["grad"])
    mtime = os.path.getmtime(grad_path)
    aot.lower_size(M.SIZES["tiny"], out, skip_existing=True)
    assert os.path.getmtime(grad_path) == mtime
