"""L2 model tests: shapes, determinism, GRPO loss semantics, and the
pallas-vs-ref differential on the full forward/backward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.SIZES["tiny"]


@pytest.fixture(scope="module")
def flat():
    return M.init_params(CFG, 0)


@pytest.fixture(scope="module")
def toks():
    return (jnp.arange(CFG.batch * CFG.seq, dtype=jnp.int32)
            .reshape(CFG.batch, CFG.seq) % CFG.vocab)


def test_param_layout_is_contiguous():
    off = 0
    for name, shape in M.param_layout(CFG):
        n = int(np.prod(shape))
        assert n > 0, name
        off += n
    assert off == M.num_params(CFG)


def test_score_shapes_and_finiteness(flat, toks):
    lp, ent = M.score(CFG, flat, toks)
    assert lp.shape == (CFG.batch, CFG.gen_len)
    assert ent.shape == (CFG.batch, CFG.gen_len)
    assert bool(jnp.isfinite(lp).all()) and bool(jnp.isfinite(ent).all())
    assert float(lp.max()) <= 0.0  # logprobs
    assert float(ent.min()) >= 0.0  # entropies


def test_rollout_prompt_preserved_and_greedy_deterministic(flat, toks):
    prompts = toks[:, :CFG.prompt_len]
    k1 = jnp.array([1, 2], jnp.uint32)
    k2 = jnp.array([3, 4], jnp.uint32)
    t0a, _ = M.rollout(CFG, flat, prompts, k1, jnp.float32(0.0))
    t0b, _ = M.rollout(CFG, flat, prompts, k2, jnp.float32(0.0))
    assert (t0a[:, :CFG.prompt_len] == prompts).all()
    # greedy ignores the key
    assert (t0a == t0b).all()
    # sampling at T=1 uses it
    t1a, _ = M.rollout(CFG, flat, prompts, k1, jnp.float32(1.0))
    t1b, _ = M.rollout(CFG, flat, prompts, k2, jnp.float32(1.0))
    assert not (t1a == t1b).all()


def test_rollout_logprobs_consistent_with_score(flat, toks):
    """The logprobs returned by rollout must equal score() on the same
    tokens (they are the behaviour-policy logprobs of Alg. H.1)."""
    prompts = toks[:, :CFG.prompt_len]
    key = jnp.array([7, 8], jnp.uint32)
    tokens, lps = M.rollout(CFG, flat, prompts, key, jnp.float32(1.0))
    lp2, _ = M.score(CFG, flat, tokens)
    # XLA fuses the scan-sliced forward differently from the full
    # forward; with BF16 compute the same math lands within ~1e-3.
    np.testing.assert_allclose(np.asarray(lps), np.asarray(lp2), rtol=2e-3,
                               atol=1e-2)


def test_grpo_zero_advantage_gives_zero_grad(flat, toks):
    adv = jnp.zeros((CFG.batch,), jnp.float32)
    old_lp, _ = M.score(CFG, flat, toks)
    mask = jnp.ones((CFG.batch, CFG.gen_len), jnp.float32)
    g, loss, *_ = M.grpo_grad(CFG, flat, toks, adv, old_lp, mask)
    assert abs(float(loss)) < 1e-8
    assert float(jnp.abs(g).max()) < 1e-8


def test_grpo_mask_excludes_tokens(flat, toks):
    adv = jnp.ones((CFG.batch,), jnp.float32)
    old_lp, _ = M.score(CFG, flat, toks)
    full = jnp.ones((CFG.batch, CFG.gen_len), jnp.float32)
    empty = jnp.zeros((CFG.batch, CFG.gen_len), jnp.float32)
    g_full, *_ = M.grpo_grad(CFG, flat, toks, adv, old_lp, full)
    g_none, *_ = M.grpo_grad(CFG, flat, toks, adv, old_lp, empty)
    assert float(jnp.abs(g_none).max()) < 1e-8
    assert float(jnp.abs(g_full).max()) > 0.0


def test_grpo_on_policy_loss_equals_minus_mean_advantage(flat, toks):
    """At ratio == 1 (on-policy), obj = A, so loss = -mean(A)."""
    adv = jnp.linspace(-1.0, 1.0, CFG.batch)
    old_lp, _ = M.score(CFG, flat, toks)
    mask = jnp.ones((CFG.batch, CFG.gen_len), jnp.float32)
    _, loss, clip_frac, mean_ratio, _ = M.grpo_grad(
        CFG, flat, toks, adv, old_lp, mask)
    np.testing.assert_allclose(float(loss), -float(adv.mean()), atol=1e-5)
    np.testing.assert_allclose(float(mean_ratio), 1.0, atol=1e-4)
    assert float(clip_frac) == 0.0


def test_gradients_are_dense(flat, toks):
    """Paper §G.1: ~99% of gradient entries are non-zero."""
    adv = jnp.ones((CFG.batch,), jnp.float32) * 0.5
    old_lp, _ = M.score(CFG, flat, toks)
    # perturb old_lp so ratios differ from 1 and gradients flow
    old_lp = old_lp - 0.01
    mask = jnp.ones((CFG.batch, CFG.gen_len), jnp.float32)
    _, _, _, _, density = M.grpo_grad(CFG, flat, toks, adv, old_lp, mask)
    assert float(density) > 0.98, float(density)


def test_pallas_and_ref_paths_agree_end_to_end(flat, toks):
    adv = jnp.linspace(-1.0, 1.0, CFG.batch)
    old_lp, _ = M.score(CFG, flat, toks)
    old_lp = old_lp - 0.02
    mask = jnp.ones((CFG.batch, CFG.gen_len), jnp.float32)
    g1, l1, *_ = M.grpo_grad(CFG, flat, toks, adv, old_lp, mask, use_pallas=True)
    g2, l2, *_ = M.grpo_grad(CFG, flat, toks, adv, old_lp, mask, use_pallas=False)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4, atol=1e-6)
    cos = float(jnp.dot(g1, g2) / (jnp.linalg.norm(g1) * jnp.linalg.norm(g2)))
    assert cos > 0.999, cos


def test_bf16_forward_view():
    """The forward pass must see the BF16 cast of the FP32 masters: two
    FP32 vectors with identical BF16 views produce identical logits
    (the compute-visibility premise)."""
    flat = M.init_params(CFG, 1)
    # sub-cell perturbation: |δ| ≤ |w|·2^-10 never crosses a BF16 cell
    # boundary from an exactly-representable start
    flat_bf = flat.astype(jnp.bfloat16).astype(jnp.float32)
    delta = flat_bf * (2.0 ** -10)
    toks = (jnp.arange(CFG.batch * CFG.seq, dtype=jnp.int32)
            .reshape(CFG.batch, CFG.seq) % CFG.vocab)
    lp1, _ = M.score(CFG, flat_bf, toks)
    lp2, _ = M.score(CFG, flat_bf + delta, toks)
    np.testing.assert_array_equal(np.asarray(lp1), np.asarray(lp2))
