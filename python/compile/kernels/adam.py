"""L1 Pallas kernel: fused AdamW step over the flat FP32 master-weight
vector.

One program instance owns a contiguous VMEM-resident chunk of
(p, m, v, g); the whole update — EMA updates, bias correction, the
θ-update, and decoupled weight decay — is fused into one pass so the
master weights stream through HBM exactly once per optimizer step.
Scalars (lr and the precomputed bias corrections) arrive as (1,)-shaped
operands broadcast to every grid cell.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1 << 14  # 16Ki f32 per operand per program instance (64 KiB)


def _adam_kernel(scalars_ref, p_ref, m_ref, v_ref, g_ref, po_ref, mo_ref, vo_ref, *, beta1,
                 beta2, eps, weight_decay):
    lr = scalars_ref[0]
    bc1 = scalars_ref[1]
    bc2 = scalars_ref[2]
    p = p_ref[...]
    g = g_ref[...]
    m2 = beta1 * m_ref[...] + (1.0 - beta1) * g
    v2 = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    mhat = m2 / bc1
    vhat = v2 / bc2
    po_ref[...] = p - lr * mhat / (jnp.sqrt(vhat) + eps) - lr * weight_decay * p
    mo_ref[...] = m2
    vo_ref[...] = v2


def adamw_step(p, m, v, g, lr, bc1, bc2, *, beta1=0.9, beta2=0.999, eps=1e-8,
               weight_decay=0.0, interpret=True):
    """Fused AdamW step on flat f32 vectors (length padded to BLOCK by
    the caller or handled via a smaller trailing grid cell).

    lr, bc1, bc2: scalars (traced). Returns (p', m', v').
    """
    n = p.shape[0]
    block = min(BLOCK, n)
    # pad to a multiple of block so the grid tiles exactly
    pad = (-n) % block
    if pad:
        p = jnp.pad(p, (0, pad))
        m = jnp.pad(m, (0, pad))
        v = jnp.pad(v, (0, pad))
        # pad gradients with zeros: a zero gradient still decays m/v but
        # the padded outputs are discarded below.
        g = jnp.pad(g, (0, pad))
    npad = p.shape[0]
    grid = (npad // block,)
    scalars = jnp.stack([lr, bc1, bc2]).astype(jnp.float32)
    kernel = functools.partial(_adam_kernel, beta1=beta1, beta2=beta2, eps=eps,
                               weight_decay=weight_decay)
    vec = pl.BlockSpec((block,), lambda i: (i,))
    sca = pl.BlockSpec((3,), lambda i: (0,))
    p2, m2, v2 = pl.pallas_call(
        kernel,
        out_shape=[jax.ShapeDtypeStruct((npad,), jnp.float32)] * 3,
        grid=grid,
        in_specs=[sca, vec, vec, vec, vec],
        out_specs=[vec, vec, vec],
        interpret=interpret,
    )(scalars, p, m, v, g)
    if pad:
        p2, m2, v2 = p2[:n], m2[:n], v2[:n]
    return p2, m2, v2
