"""L1 Pallas kernel: the compute-visibility gate (paper Eq. 1).

Elementwise over the flat parameter vector: emit 1 where
cast_BF16(θ) ≠ cast_BF16(θ − s). This is the paper's central operation;
the Rust coordinator has a native implementation on its hot path, and
this kernel is the AOT-compiled equivalent used for the L1↔L3 ablation
(bench_gate) and as part of the exported artifact set.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1 << 15


def _gate_kernel(theta_ref, s_ref, mask_ref):
    theta = theta_ref[...]
    s = s_ref[...]
    before = theta.astype(jnp.bfloat16)
    after = (theta - s).astype(jnp.bfloat16)
    mask_ref[...] = (before != after).astype(jnp.uint8)


def visibility_gate(theta, s, interpret=True):
    """BF16 compute-visibility gate over flat f32 vectors → u8 mask."""
    n = theta.shape[0]
    block = min(BLOCK, n)
    pad = (-n) % block
    if pad:
        theta = jnp.pad(theta, (0, pad))
        s = jnp.pad(s, (0, pad))
    npad = theta.shape[0]
    vec = pl.BlockSpec((block,), lambda i: (i,))
    mask = pl.pallas_call(
        _gate_kernel,
        out_shape=jax.ShapeDtypeStruct((npad,), jnp.uint8),
        grid=(npad // block,),
        in_specs=[vec, vec],
        out_specs=vec,
        interpret=interpret,
    )(theta, s)
    return mask[:n] if pad else mask
