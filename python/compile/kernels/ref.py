"""Pure-jnp oracles for every Pallas kernel (the CORE correctness
signal: pytest asserts kernel == ref under hypothesis-driven sweeps).

These references are deliberately written with plain jnp ops, no pallas,
so a bug in the kernels cannot hide in shared code.
"""

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, scale=None):
    """Causal multi-head attention.

    q, k, v: [B, H, T, hd] (any float dtype). Returns [B, H, T, hd] in
    q.dtype; softmax accumulates in f32.
    """
    B, H, T, hd = q.shape
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    mask = jnp.tril(jnp.ones((T, T), dtype=bool))
    s = jnp.where(mask[None, None, :, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def adamw_ref(p, m, v, g, lr, beta1, beta2, eps, weight_decay, bc1, bc2):
    """One fused AdamW step (bias corrections bc1 = 1-beta1^t etc. are
    precomputed scalars, matching the kernel's interface)."""
    m2 = beta1 * m + (1.0 - beta1) * g
    v2 = beta2 * v + (1.0 - beta2) * g * g
    mhat = m2 / bc1
    vhat = v2 / bc2
    p2 = p - lr * mhat / (jnp.sqrt(vhat) + eps) - lr * weight_decay * p
    return p2, m2, v2


def gate_ref(theta, s):
    """Compute-visibility gate (paper Eq. 1), D = BF16: 1 where the BF16
    view of theta changes after applying update s (new value theta - s).
    """
    before = theta.astype(jnp.bfloat16)
    after = (theta - s).astype(jnp.bfloat16)
    return (before != after).astype(jnp.uint8)
