"""L1 Pallas kernel: fused causal attention — the forward-pass hot spot
of the L2 transformer (DESIGN.md §Hardware-Adaptation).

TPU mapping: the grid iterates (batch, head); each program instance
holds one head's Q/K/V tile in VMEM, runs the T×T score matmul on the
MXU, applies the causal mask and a numerically-stable softmax in f32,
and writes the output tile. For the sequence lengths used here
(T ≤ 64, hd ≤ 64) one (T, hd) tile per head fits VMEM comfortably
(≤ 64·64·4 B = 16 KB per operand; VMEM budget analysis in DESIGN.md).

MUST be lowered with interpret=True — real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale):
    # Block shapes are (1, 1, T, hd): squeeze the unit grid dims.
    q = q_ref[0, 0].astype(jnp.float32)  # [T, hd]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    T = q.shape[0]
    s = jnp.dot(q, k.T) * scale  # MXU matmul, [T, T]
    causal = jnp.tril(jnp.ones((T, T), dtype=bool))
    s = jnp.where(causal, s, -1e30)
    # stable softmax in f32
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.dot(p, v)  # [T, hd]
    o_ref[0, 0] = o.astype(o_ref.dtype)


def _attn_fwd_call(q, k, v, scale, interpret):
    B, H, T, hd = q.shape
    kernel = functools.partial(_attn_kernel, scale=scale)
    block = pl.BlockSpec((1, 1, T, hd), lambda b, h: (b, h, 0, 0))
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        grid=(B, H),
        in_specs=[block, block, block],
        out_specs=block,
        interpret=interpret,
    )(q, k, v)


def _attn_bwd_kernel(q_ref, k_ref, v_ref, do_ref, dq_ref, dk_ref, dv_ref, *, scale):
    """Flash-style backward: recompute P from (q, k) in VMEM, then
      dV = Pᵀ dO ; dP = dO Vᵀ ; dS = P ∘ (dP − rowsum(dP ∘ P))
      dQ = scale · dS K ; dK = scale · dSᵀ Q
    """
    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    T = q.shape[0]
    s = jnp.dot(q, k.T) * scale
    causal = jnp.tril(jnp.ones((T, T), dtype=bool))
    s = jnp.where(causal, s, -1e30)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    dv = jnp.dot(p.T, do)
    dp = jnp.dot(do, v.T)
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = jnp.dot(ds, k) * scale
    dk = jnp.dot(ds.T, q) * scale
    dq_ref[0, 0] = dq.astype(dq_ref.dtype)
    dk_ref[0, 0] = dk.astype(dk_ref.dtype)
    dv_ref[0, 0] = dv.astype(dv_ref.dtype)


def _attn_bwd_call(q, k, v, do, scale, interpret):
    B, H, T, hd = q.shape
    kernel = functools.partial(_attn_bwd_kernel, scale=scale)
    block = pl.BlockSpec((1, 1, T, hd), lambda b, h: (b, h, 0, 0))
    shapes = [jax.ShapeDtypeStruct(q.shape, q.dtype)] * 3
    return pl.pallas_call(
        kernel,
        out_shape=shapes,
        grid=(B, H),
        in_specs=[block, block, block, block],
        out_specs=[block, block, block],
        interpret=interpret,
    )(q, k, v, do)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def attention(q, k, v, scale=None, interpret=True):
    """Causal attention via pallas_call. q/k/v: [B, H, T, hd].

    Differentiable: forward and backward are both Pallas kernels
    (pallas_call has no built-in autodiff, so we provide a custom VJP).
    """
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    return _attn_fwd_call(q, k, v, scale, interpret)


def _attention_fwd(q, k, v, scale, interpret):
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    o = _attn_fwd_call(q, k, v, scale, interpret)
    return o, (q, k, v)


def _attention_bwd(scale, interpret, res, do):
    q, k, v = res
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    dq, dk, dv = _attn_bwd_call(q, k, v, do, scale, interpret)
    return dq, dk, dv


attention.defvjp(_attention_fwd, _attention_bwd)
