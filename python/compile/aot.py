"""AOT build step: lower every L2 graph to HLO **text** and write the
manifests the Rust runtime consumes. Runs once (`make artifacts`);
python never executes on the request path.

HLO text — NOT ``lowered.compile()`` / ``.serialize()`` — is the
interchange format: jax ≥ 0.5 emits HloModuleProtos with 64-bit
instruction ids which the xla crate's xla_extension 0.5.1 rejects; the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and DESIGN.md).

Per model size this writes:
    artifacts/<size>.rollout.hlo.txt   (flat, prompts, key, temp) →
                                       (tokens, logprobs)
    artifacts/<size>.grad.hlo.txt      (flat, tokens, adv, old_lp, mask) →
                                       (grads, loss, clip, ratio, density)
    artifacts/<size>.score.hlo.txt     (flat, tokens) → (logprobs, entropy)
    artifacts/<size>.gate.hlo.txt      (theta, s) → u8 mask   [L1 kernel]
    artifacts/<size>.adam.hlo.txt      (scalars, p, m, v, g) → (p', m', v')
    artifacts/<size>.init.bin          f32-LE flat init (tiny/small/med)
    artifacts/<size>.meta.json         layout + dims + oracle block
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import adam as adam_kernel
from .kernels import gate as gate_kernel

# Sizes that ship an init.bin + numeric oracle (cross-language check).
ORACLE_SIZES = ("tiny", "small", "med")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_size(cfg: M.ModelConfig, out_dir: str, skip_existing: bool = True,
               with_oracle: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    n = M.num_params(cfg)
    f32 = jnp.float32
    i32 = jnp.int32
    flat_spec = jax.ShapeDtypeStruct((n,), f32)
    tok_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), i32)
    prompt_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.prompt_len), i32)
    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    scalar_spec = jax.ShapeDtypeStruct((), f32)
    adv_spec = jax.ShapeDtypeStruct((cfg.batch,), f32)
    glp_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.gen_len), f32)

    def emit(name, fn, *specs):
        path = os.path.join(out_dir, f"{cfg.name}.{name}.hlo.txt")
        if skip_existing and os.path.exists(path):
            print(f"  [skip] {path}")
            return os.path.basename(path)
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"  [ok] {path} ({len(text)} chars)")
        return os.path.basename(path)

    artifacts = {}
    artifacts["score"] = emit(
        "score", lambda p, t: M.score(cfg, p, t), flat_spec, tok_spec)
    artifacts["rollout"] = emit(
        "rollout", lambda p, pr, k, temp: M.rollout(cfg, p, pr, k, temp),
        flat_spec, prompt_spec, key_spec, scalar_spec)
    artifacts["grad"] = emit(
        "grad",
        lambda p, t, a, olp, m: M.grpo_grad(cfg, p, t, a, olp, m),
        flat_spec, tok_spec, adv_spec, glp_spec, glp_spec)
    # L1 kernels exported as standalone executables over this size's N.
    artifacts["gate"] = emit(
        "gate",
        lambda theta, s: (gate_kernel.visibility_gate(theta, s),),
        flat_spec, flat_spec)
    artifacts["adam"] = emit(
        "adam",
        lambda sc, p, m, v, g: adam_kernel.adamw_step(
            p, m, v, g, sc[0], sc[1], sc[2]),
        jax.ShapeDtypeStruct((3,), f32), flat_spec, flat_spec, flat_spec,
        flat_spec)

    meta = {
        "name": cfg.name,
        "n_params": n,
        "dims": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "seq": cfg.seq,
            "prompt_len": cfg.prompt_len,
            "gen_len": cfg.gen_len,
            "batch": cfg.batch,
            "d_ff": cfg.d_ff,
        },
        "artifacts": artifacts,
        "tensors": [],
        "eps_low": M.EPS_LOW,
        "eps_high": M.EPS_HIGH,
    }
    off = 0
    for name, shape in M.param_layout(cfg):
        size = int(np.prod(shape))
        meta["tensors"].append(
            {"name": name, "shape": list(shape), "offset": off, "len": size})
        off += size
    assert off == n

    if with_oracle and cfg.name in ORACLE_SIZES:
        init_path = os.path.join(out_dir, f"{cfg.name}.init.bin")
        flat = np.asarray(M.init_params(cfg, 0), dtype=np.float32)
        flat.tofile(init_path)
        meta["init"] = f"{cfg.name}.init.bin"
        # Numeric oracle: run score on a fixed token grid, record a
        # fingerprint the Rust integration test must reproduce via the
        # AOT-compiled HLO.
        toks = (np.arange(cfg.batch * cfg.seq, dtype=np.int32)
                .reshape(cfg.batch, cfg.seq) % cfg.vocab)
        lp, ent = M.score(cfg, jnp.asarray(flat), jnp.asarray(toks))
        lp = np.asarray(lp, dtype=np.float64)
        meta["oracle"] = {
            "tokens": "arange % vocab",
            "logprob_sum": float(lp.sum()),
            "logprob_first8": [float(x) for x in lp.reshape(-1)[:8]],
            "entropy_mean": float(np.asarray(ent).mean()),
        }

    meta_path = os.path.join(out_dir, f"{cfg.name}.meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=1)
    print(f"  [ok] {meta_path}")
    return meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output directory (default ../artifacts)")
    ap.add_argument("--sizes", default="tiny,small,med",
                    help="comma-separated model sizes "
                         f"(available: {','.join(M.SIZES)})")
    ap.add_argument("--force", action="store_true",
                    help="re-lower even if the artifact exists")
    args = ap.parse_args()
    for size in args.sizes.split(","):
        size = size.strip()
        if size not in M.SIZES:
            print(f"unknown size '{size}'", file=sys.stderr)
            sys.exit(2)
        print(f"[aot] lowering {size} ...")
        lower_size(M.SIZES[size], args.out, skip_existing=not args.force)


if __name__ == "__main__":
    main()
