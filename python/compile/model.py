"""L2: the JAX model — a GPT-style transformer LM plus the three graphs
the Rust coordinator executes via PJRT:

* ``score(params, tokens)``         → per-completion-token logprobs
* ``rollout(params, prompts, key, temperature)`` → sampled tokens + logprobs
* ``grpo_grad(params, tokens, advantages, old_logprobs, mask)``
                                    → flat grads + loss diagnostics

All graphs take the parameters as ONE flat f32 vector (the layout is
described by the manifest emitted by aot.py). The forward pass runs on
the BF16 cast of the parameters — exactly the compute-visibility
criterion of the paper: an FP32 master update matters iff it changes
this cast. The attention hot spot is the L1 Pallas kernel
(kernels/attention.py); set use_pallas=False to get the pure-jnp path
used for differential testing.
"""

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels import attention as attn_kernel
from .kernels import ref as kref

# GRPO asymmetric clipping (DAPO): paper Table 8.
EPS_LOW = 0.2
EPS_HIGH = 0.28


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    seq: int          # T = prompt_len + gen_len
    prompt_len: int
    gen_len: int
    batch: int        # rollout/grad batch (sequences)
    d_ff: int = 0     # 0 → 4 * d_model

    def __post_init__(self):
        if self.d_ff == 0:
            object.__setattr__(self, "d_ff", 4 * self.d_model)
        assert self.seq == self.prompt_len + self.gen_len
        assert self.d_model % self.n_heads == 0


# The model zoo standing in for the paper's Qwen/Llama/Gemma suite
# (DESIGN.md §2). Parameter counts: tiny≈0.12M, small≈0.85M, med≈4.8M,
# large≈25.4M, xl≈113M.
SIZES = {
    "tiny": ModelConfig("tiny", 64, 64, 2, 2, 24, 16, 8, 32),
    "small": ModelConfig("small", 64, 128, 4, 4, 24, 16, 8, 32),
    "med": ModelConfig("med", 64, 256, 6, 8, 24, 16, 8, 32),
    "large": ModelConfig("large", 64, 512, 8, 8, 24, 16, 8, 16),
    "xl": ModelConfig("xl", 64, 768, 16, 12, 24, 16, 8, 16),
}


def param_layout(cfg: ModelConfig):
    """Deterministic (name, shape) list defining the flat vector layout.
    The Rust runtime reads the same layout from the manifest."""
    specs = [
        ("embed", (cfg.vocab, cfg.d_model)),
        ("pos", (cfg.seq, cfg.d_model)),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        specs += [
            (p + "ln1_g", (cfg.d_model,)),
            (p + "ln1_b", (cfg.d_model,)),
            (p + "wq", (cfg.d_model, cfg.d_model)),
            (p + "wk", (cfg.d_model, cfg.d_model)),
            (p + "wv", (cfg.d_model, cfg.d_model)),
            (p + "wo", (cfg.d_model, cfg.d_model)),
            (p + "ln2_g", (cfg.d_model,)),
            (p + "ln2_b", (cfg.d_model,)),
            (p + "w1", (cfg.d_model, cfg.d_ff)),
            (p + "b1", (cfg.d_ff,)),
            (p + "w2", (cfg.d_ff, cfg.d_model)),
            (p + "b2", (cfg.d_model,)),
        ]
    specs += [("lnf_g", (cfg.d_model,)), ("lnf_b", (cfg.d_model,))]
    return specs


def num_params(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_layout(cfg))


def init_params(cfg: ModelConfig, seed: int = 0) -> jnp.ndarray:
    """Magnitude-calibrated init (DESIGN.md: matches the LLM-like |w|
    scale of paper Table 2): scaled-normal matrices, ones/zeros LNs."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for name, shape in param_layout(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("_g",)):
            w = jnp.ones(shape, jnp.float32)
        elif name.endswith(("_b", "b1", "b2")):
            w = jnp.zeros(shape, jnp.float32)
        elif name == "embed" or name == "pos":
            w = 0.02 * jax.random.normal(sub, shape, jnp.float32)
        else:
            fan_in = shape[0]
            w = jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(fan_in)
        chunks.append(w.reshape(-1))
    return jnp.concatenate(chunks)


def unflatten(cfg: ModelConfig, flat: jnp.ndarray, dtype=jnp.bfloat16):
    """Slice the flat vector into the named parameter dict, cast to the
    compute dtype (the BF16 forward-pass view of the paper)."""
    params = {}
    off = 0
    for name, shape in param_layout(cfg):
        n = 1
        for d in shape:
            n *= d
        params[name] = flat[off:off + n].reshape(shape).astype(dtype)
        off += n
    return params


def _layernorm(x, g, b):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) / jnp.sqrt(var + 1e-5)
    return (y * g.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def forward_logits(cfg: ModelConfig, params, tokens, use_pallas=True):
    """Transformer forward. tokens: [B, T] int32 → logits [B, T, V] f32."""
    B, T = tokens.shape
    x = params["embed"][tokens] + params["pos"][None, :T, :]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        h = _layernorm(x, params[p + "ln1_g"], params[p + "ln1_b"])
        q = h @ params[p + "wq"]
        k = h @ params[p + "wk"]
        v = h @ params[p + "wv"]
        hd = cfg.d_model // cfg.n_heads

        def split(z):
            return z.reshape(B, T, cfg.n_heads, hd).transpose(0, 2, 1, 3)

        q, k, v = split(q), split(k), split(v)
        if use_pallas:
            o = attn_kernel.attention(q, k, v)
        else:
            o = kref.attention_ref(q, k, v)
        o = o.transpose(0, 2, 1, 3).reshape(B, T, cfg.d_model)
        x = x + o @ params[p + "wo"]
        h = _layernorm(x, params[p + "ln2_g"], params[p + "ln2_b"])
        h = jax.nn.gelu(h @ params[p + "w1"] + params[p + "b1"])
        x = x + h @ params[p + "w2"] + params[p + "b2"]
    x = _layernorm(x, params["lnf_g"], params["lnf_b"])
    # tied unembedding; logits in f32 for a stable softmax
    logits = jnp.einsum("btd,vd->btv", x.astype(jnp.float32),
                        params["embed"].astype(jnp.float32))
    return logits


def completion_logprobs(cfg: ModelConfig, logits, tokens):
    """Logprob of each generated token: positions P..T-1 predicted from
    P-1..T-2. Returns [B, G] f32."""
    P = cfg.prompt_len
    pred = logits[:, P - 1:cfg.seq - 1, :]          # [B, G, V]
    lp = jax.nn.log_softmax(pred, axis=-1)
    chosen = tokens[:, P:cfg.seq]                   # [B, G]
    return jnp.take_along_axis(lp, chosen[..., None], axis=-1)[..., 0]


def score(cfg: ModelConfig, flat_params, tokens, use_pallas=True):
    """(flat_params, tokens[B,T]) → (logprobs[B,G], entropy[B,G])."""
    params = unflatten(cfg, flat_params)
    logits = forward_logits(cfg, params, tokens, use_pallas)
    lp = completion_logprobs(cfg, logits, tokens)
    pred = logits[:, cfg.prompt_len - 1:cfg.seq - 1, :]
    logp_all = jax.nn.log_softmax(pred, axis=-1)
    entropy = -jnp.sum(jnp.exp(logp_all) * logp_all, axis=-1)
    return lp, entropy


def rollout(cfg: ModelConfig, flat_params, prompts, key, temperature,
            use_pallas=True):
    """Autoregressive generation of gen_len tokens.

    prompts: [B, P] int32; key: uint32[2]; temperature: f32 scalar
    (exactly 0 → greedy, via the Gumbel-max trick: argmax(logits +
    T·gumbel) is greedy at T=0 and categorical sampling at T=1).
    Returns (tokens [B, T], logprobs [B, G] of the chosen tokens under
    the current policy).
    """
    B, P = prompts.shape
    assert P == cfg.prompt_len
    params = unflatten(cfg, flat_params)
    tokens0 = jnp.concatenate(
        [prompts, jnp.zeros((B, cfg.gen_len), dtype=prompts.dtype)], axis=1)

    def step(tokens, g):
        logits = forward_logits(cfg, params, tokens, use_pallas)
        pos = P + g - 1
        next_logits = jax.lax.dynamic_slice_in_dim(logits, pos, 1, axis=1)[:, 0, :]
        sub = jax.random.fold_in(jax.random.wrap_key_data(key, impl="threefry2x32"), g)
        gumbel = jax.random.gumbel(sub, next_logits.shape, jnp.float32)
        sample = jnp.argmax(next_logits + temperature * gumbel, axis=-1)
        lp = jnp.take_along_axis(jax.nn.log_softmax(next_logits, axis=-1),
                                 sample[:, None], axis=-1)[:, 0]
        tokens = jax.lax.dynamic_update_slice_in_dim(
            tokens, sample[:, None].astype(tokens.dtype), P + g, axis=1)
        return tokens, lp

    tokens, lps = jax.lax.scan(step, tokens0, jnp.arange(cfg.gen_len))
    return tokens, lps.T  # [B, T], [B, G]


def grpo_loss(cfg: ModelConfig, flat_params, tokens, advantages, old_logprobs,
              mask, use_pallas=True):
    """GRPO clipped-surrogate loss (paper §H.1, KL term omitted
    following DAPO). mask: [B, G] f32, 1 for real completion tokens."""
    params = unflatten(cfg, flat_params)
    logits = forward_logits(cfg, params, tokens, use_pallas)
    lp = completion_logprobs(cfg, logits, tokens)          # [B, G]
    ratio = jnp.exp(lp - old_logprobs)
    adv = advantages[:, None]
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - EPS_LOW, 1.0 + EPS_HIGH) * adv
    obj = jnp.minimum(unclipped, clipped) * mask
    denom = jnp.maximum(jnp.sum(mask, axis=1), 1.0)
    per_seq = jnp.sum(obj, axis=1) / denom
    loss = -jnp.mean(per_seq)
    clip_frac = jnp.sum((unclipped > clipped).astype(jnp.float32) * mask) / jnp.maximum(
        jnp.sum(mask), 1.0)
    mean_ratio = jnp.sum(ratio * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, (clip_frac, mean_ratio)


def grpo_grad(cfg: ModelConfig, flat_params, tokens, advantages, old_logprobs,
              mask, use_pallas=True):
    """Returns (grads [N] f32, loss, clip_frac, mean_ratio, grad_density)."""
    (loss, (clip_frac, mean_ratio)), grads = jax.value_and_grad(
        lambda p: grpo_loss(cfg, p, tokens, advantages, old_logprobs, mask,
                            use_pallas), has_aux=True)(flat_params)
    grad_density = jnp.mean((grads != 0.0).astype(jnp.float32))
    return grads, loss, clip_frac, mean_ratio, grad_density


def make_jitted(cfg: ModelConfig, use_pallas=True):
    """Jitted entry points with the exact signatures aot.py exports."""
    n = num_params(cfg)

    def _score(flat, tokens):
        return score(cfg, flat, tokens, use_pallas)

    def _rollout(flat, prompts, key, temperature):
        return rollout(cfg, flat, prompts, key, temperature, use_pallas)

    def _grad(flat, tokens, advantages, old_logprobs, mask):
        return grpo_grad(cfg, flat, tokens, advantages, old_logprobs, mask,
                         use_pallas)

    return {
        "n_params": n,
        "score": jax.jit(_score),
        "rollout": jax.jit(_rollout),
        "grad": jax.jit(_grad),
    }
