//! Offline stand-in for the `anyhow` crate, exposing the subset of its
//! API this workspace uses: [`Error`], [`Result`], the [`Context`]
//! trait, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! The build image has no crates.io access, so the workspace vendors
//! path dependencies instead of registry ones (see `vendor/README.md`).
//! Swapping this for the real crate is a one-line change in
//! `rust/Cargo.toml`; nothing here extends the real crate's surface.
//!
//! Internals are simpler than real anyhow: an error is an owned chain
//! of human-readable messages (outermost context first). `Display`
//! shows the outermost message, `{:#}` joins the whole chain with
//! `": "`, and `Debug` renders the multi-line "Caused by" form —
//! matching how the three formats are conventionally consumed.

use std::fmt;

/// Error chain: `chain[0]` is the outermost (most recent) context.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain on one line.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.split_first() {
            None => Ok(()),
            Some((head, rest)) => {
                write!(f, "{}", head)?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for cause in rest {
                        write!(f, "\n    {}", cause)?;
                    }
                }
                Ok(())
            }
        }
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Drop-in alias for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context()` / `.with_context()` to `Result`
/// and `Option`, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: Into<Error>,
{
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = io_err().into();
        let e = e.context("reading header");
        assert_eq!(format!("{}", e), "reading header");
        assert_eq!(format!("{:#}", e), "reading header: disk on fire");
        let dbg = format!("{:?}", e);
        assert!(dbg.contains("Caused by"));
        assert!(dbg.contains("disk on fire"));
    }

    #[test]
    fn macros_and_context() {
        fn fails() -> Result<()> {
            bail!("bad {}", 7);
        }
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "bad 7");

        fn guarded(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {}", x);
            Ok(x)
        }
        assert!(guarded(1).is_ok());
        assert_eq!(guarded(-2).unwrap_err().to_string(), "x must be positive, got -2");

        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{:#}", e), "step 3: disk on fire");

        let o: Option<u8> = None;
        assert_eq!(o.context("missing byte").unwrap_err().to_string(), "missing byte");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().root_cause(), "disk on fire");
    }
}
