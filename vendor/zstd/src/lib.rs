//! Offline stand-in for the `zstd` crate's `bulk` API. Backed by the
//! vendored [`lzcore`] LZSS codec — **not** zstd wire format (see
//! `vendor/README.md` and `lzcore`'s crate docs for why this is safe
//! in this workspace: the stream is only ever read back by the same
//! library, and stored containers carry a codec tag). Signatures match
//! `zstd::bulk`, so restoring the real crate is a manifest-only change.

pub mod bulk {
    use std::io;

    /// Compress `data` at `level` (levels are accepted for API parity;
    /// the backing LZSS matcher is level-independent).
    pub fn compress(data: &[u8], level: i32) -> io::Result<Vec<u8>> {
        Ok(lzcore::compress(data, level))
    }

    /// Decompress, allocating at most `capacity` output bytes — same
    /// contract as `zstd::bulk::decompress` (errors if the frame's
    /// declared content size exceeds `capacity`).
    pub fn decompress(data: &[u8], capacity: usize) -> io::Result<Vec<u8>> {
        lzcore::decompress(data, capacity)
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn bulk_roundtrip_and_capacity() {
        let data = vec![9u8; 50_000];
        let c = super::bulk::compress(&data, 1).unwrap();
        assert!(c.len() < data.len() / 10);
        assert_eq!(super::bulk::decompress(&c, data.len()).unwrap(), data);
        assert!(super::bulk::decompress(&c, data.len() - 1).is_err());
    }
}
