//! Byte-oriented LZSS codec shared by the offline `zstd` and `flate2`
//! shims (this workspace has no crates.io access; see
//! `vendor/README.md`). **The stream format here is NOT zstd or
//! DEFLATE wire format** — it is a self-describing private format that
//! the paired shims both write and read. Nothing in the workspace
//! persists these streams across implementations: compressed bytes are
//! always decompressed by the same library that produced them, so
//! swapping the real crates back in only requires re-encoding stored
//! objects (the PULSESync container records the codec tag, so readers
//! fail loudly, not silently).
//!
//! Stream layout:
//!
//! ```text
//!   varint raw_len
//!   repeated groups: control byte (LSB-first; bit=1 → match) then 8
//!   items; literal = 1 raw byte, match = u16 LE distance (>=1) +
//!   u8 (length - MIN_MATCH), lengths MIN_MATCH..=MIN_MATCH+255
//! ```
//!
//! Compression quality is LZ4-class (greedy hash-table matcher, no
//! entropy stage): constant/structured data shrinks by orders of
//! magnitude, incompressible data expands by at most 1 bit per byte
//! plus the header.

use std::io;

const MIN_MATCH: usize = 4;
const MAX_MATCH: usize = MIN_MATCH + 255;
const MAX_DISTANCE: usize = 65535;
const HASH_BITS: u32 = 15;

fn err(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

fn write_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

fn read_varint(data: &[u8], pos: &mut usize) -> io::Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let byte = *data.get(*pos).ok_or_else(|| err("truncated varint"))?;
        *pos += 1;
        if shift >= 63 && byte > 1 {
            return Err(err("varint overflow"));
        }
        v |= u64::from(byte & 0x7f) << shift;
        if byte & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

#[inline]
fn hash4(data: &[u8], i: usize) -> usize {
    let word = u32::from_le_bytes([data[i], data[i + 1], data[i + 2], data[i + 3]]);
    (word.wrapping_mul(2654435761) >> (32 - HASH_BITS)) as usize
}

/// Compress `data`. `level` is accepted for API parity with the real
/// codecs; all levels currently use the same greedy matcher.
pub fn compress(data: &[u8], _level: i32) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + data.len() / 2);
    write_varint(&mut out, data.len() as u64);

    let mut table = vec![usize::MAX; 1 << HASH_BITS];
    // pending items within the current control group
    let mut control = 0u8;
    let mut nbits = 0u8;
    let mut group: Vec<u8> = Vec::with_capacity(8 * 3);
    let mut control_slot: Option<usize> = None;

    macro_rules! begin_item {
        ($is_match:expr) => {
            if nbits == 0 {
                control_slot = Some(out.len());
                out.push(0);
            }
            if $is_match {
                control |= 1 << nbits;
            }
            nbits += 1;
        };
    }
    macro_rules! flush_group {
        () => {
            if nbits > 0 {
                out[control_slot.unwrap()] = control;
                out.extend_from_slice(&group);
                group.clear();
                control = 0;
                nbits = 0;
                control_slot = None;
            }
        };
    }

    let mut i = 0usize;
    while i < data.len() {
        let mut matched = 0usize;
        let mut dist = 0usize;
        if i + MIN_MATCH <= data.len() {
            let h = hash4(data, i);
            let cand = table[h];
            table[h] = i;
            if cand != usize::MAX && i - cand <= MAX_DISTANCE {
                // verify and extend
                let max_len = MAX_MATCH.min(data.len() - i);
                let mut l = 0usize;
                while l < max_len && data[cand + l] == data[i + l] {
                    l += 1;
                }
                if l >= MIN_MATCH {
                    matched = l;
                    dist = i - cand;
                }
            }
        }
        if matched >= MIN_MATCH {
            begin_item!(true);
            group.extend_from_slice(&(dist as u16).to_le_bytes());
            group.push((matched - MIN_MATCH) as u8);
            // seed the table sparsely inside the match to keep the
            // matcher O(n) on highly repetitive data
            let step = 1 + matched / 8;
            let mut j = i + 1;
            while j + MIN_MATCH <= data.len() && j < i + matched {
                table[hash4(data, j)] = j;
                j += step;
            }
            i += matched;
        } else {
            begin_item!(false);
            group.push(data[i]);
            i += 1;
        }
        if nbits == 8 {
            flush_group!();
        }
    }
    flush_group!();
    out
}

/// Decompress a stream produced by [`compress`]. The declared raw
/// length is validated against `max_len` before allocation so a
/// corrupted header cannot trigger an outsized allocation.
pub fn decompress(data: &[u8], max_len: usize) -> io::Result<Vec<u8>> {
    let mut pos = 0usize;
    let raw_len = read_varint(data, &mut pos)? as usize;
    if raw_len > max_len {
        return Err(err("declared length exceeds limit"));
    }
    let mut out = Vec::with_capacity(raw_len);
    while out.len() < raw_len {
        let control = *data.get(pos).ok_or_else(|| err("truncated control byte"))?;
        pos += 1;
        for bit in 0..8 {
            if out.len() == raw_len {
                break;
            }
            if control & (1 << bit) != 0 {
                if pos + 3 > data.len() {
                    return Err(err("truncated match"));
                }
                let dist = u16::from_le_bytes([data[pos], data[pos + 1]]) as usize;
                let len = data[pos + 2] as usize + MIN_MATCH;
                pos += 3;
                if dist == 0 || dist > out.len() {
                    return Err(err("match distance out of range"));
                }
                if out.len() + len > raw_len {
                    return Err(err("match overruns declared length"));
                }
                // overlapping copy must be byte-at-a-time
                let start = out.len() - dist;
                for k in 0..len {
                    let b = out[start + k];
                    out.push(b);
                }
            } else {
                let b = *data.get(pos).ok_or_else(|| err("truncated literal"))?;
                pos += 1;
                out.push(b);
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(data: &[u8]) {
        let c = compress(data, 1);
        let d = decompress(&c, data.len()).unwrap();
        assert_eq!(d, data, "roundtrip failed for len {}", data.len());
    }

    #[test]
    fn roundtrips() {
        roundtrip(b"");
        roundtrip(b"a");
        roundtrip(b"abcabcabcabcabcabcabcabc");
        roundtrip(&vec![7u8; 100_000]);
        let patterned: Vec<u8> = (0..50_000u32).map(|i| (i % 251) as u8).collect();
        roundtrip(&patterned);
        // pseudo-random (LCG) incompressible-ish data
        let mut x = 12345u64;
        let random: Vec<u8> = (0..30_000)
            .map(|_| {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (x >> 33) as u8
            })
            .collect();
        roundtrip(&random);
    }

    #[test]
    fn constant_data_shrinks_hard() {
        let data = vec![42u8; 100_000];
        let c = compress(&data, 1);
        assert!(c.len() < data.len() / 50, "compressed to {}", c.len());
    }

    #[test]
    fn truncation_and_corruption_error_cleanly() {
        let data: Vec<u8> = (0..10_000u32).map(|i| (i % 17) as u8).collect();
        let c = compress(&data, 1);
        for cut in [0usize, 1, c.len() / 2, c.len() - 1] {
            assert!(decompress(&c[..cut], data.len()).is_err(), "cut {}", cut);
        }
        // limit enforced before allocation
        assert!(decompress(&c, data.len() - 1).is_err());
    }

    #[test]
    fn overlapping_matches() {
        // RLE-style: match distance 1
        let mut data = vec![b'x'];
        data.extend(std::iter::repeat(b'y').take(1000));
        roundtrip(&data);
    }
}
