//! Offline stand-in for the `flate2` crate surface this workspace
//! uses: `Compression`, `write::GzEncoder`, `read::GzDecoder`. Backed
//! by the vendored [`lzcore`] LZSS codec — **not** DEFLATE/gzip wire
//! format (see `vendor/README.md`; streams are only read back by this
//! same library and containers carry a codec tag). Signatures match
//! `flate2`, so restoring the real crate is a manifest-only change.

use std::io::{self, Read, Write};

/// Hard ceiling on a decoded stream, so a corrupted header cannot
/// trigger an outsized allocation (the flate2 API carries no expected
/// output size).
const MAX_DECODED: usize = 1 << 31;

/// Compression level wrapper (API parity; the LZSS backend is
/// level-independent).
#[derive(Debug, Clone, Copy)]
pub struct Compression(u32);

impl Compression {
    pub fn new(level: u32) -> Compression {
        Compression(level)
    }
    pub fn level(&self) -> u32 {
        self.0
    }
}

pub mod write {
    use super::*;

    /// Buffering encoder: bytes written are compressed as one stream on
    /// [`GzEncoder::finish`], which hands back the inner writer.
    pub struct GzEncoder<W: Write> {
        inner: W,
        buf: Vec<u8>,
        level: u32,
    }

    impl<W: Write> GzEncoder<W> {
        pub fn new(inner: W, level: Compression) -> GzEncoder<W> {
            GzEncoder { inner, buf: Vec::new(), level: level.level() }
        }

        pub fn finish(mut self) -> io::Result<W> {
            let compressed = lzcore::compress(&self.buf, self.level as i32);
            self.inner.write_all(&compressed)?;
            self.inner.flush()?;
            Ok(self.inner)
        }
    }

    impl<W: Write> Write for GzEncoder<W> {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            self.buf.extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }
}

pub mod read {
    use super::*;

    /// Decoder: drains the inner reader on first read, decompresses,
    /// then serves the decoded bytes.
    pub struct GzDecoder<R: Read> {
        inner: Option<R>,
        decoded: Vec<u8>,
        pos: usize,
    }

    impl<R: Read> GzDecoder<R> {
        pub fn new(inner: R) -> GzDecoder<R> {
            GzDecoder { inner: Some(inner), decoded: Vec::new(), pos: 0 }
        }
    }

    impl<R: Read> Read for GzDecoder<R> {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            if let Some(mut inner) = self.inner.take() {
                let mut raw = Vec::new();
                inner.read_to_end(&mut raw)?;
                self.decoded = lzcore::decompress(&raw, MAX_DECODED)?;
            }
            let n = buf.len().min(self.decoded.len() - self.pos);
            buf[..n].copy_from_slice(&self.decoded[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encoder_decoder_roundtrip() {
        let data: Vec<u8> = (0..40_000u32).map(|i| (i % 97) as u8).collect();
        let mut enc = write::GzEncoder::new(Vec::new(), Compression::new(6));
        enc.write_all(&data).unwrap();
        let compressed = enc.finish().unwrap();
        assert!(compressed.len() < data.len());
        let mut out = Vec::new();
        read::GzDecoder::new(&compressed[..]).read_to_end(&mut out).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn corrupt_stream_errors() {
        let mut enc = write::GzEncoder::new(Vec::new(), Compression::new(6));
        enc.write_all(&vec![5u8; 1000]).unwrap();
        let mut compressed = enc.finish().unwrap();
        compressed.truncate(compressed.len() / 2);
        let mut out = Vec::new();
        assert!(read::GzDecoder::new(&compressed[..]).read_to_end(&mut out).is_err());
    }
}
