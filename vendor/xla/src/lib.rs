//! Stub of the `xla` PJRT bindings used by `pulse::runtime`.
//!
//! The real crate links the XLA C++ runtime, which this offline image
//! cannot build. This stub keeps the whole L3 crate compiling with the
//! same call-site API; every entry point that would touch PJRT returns
//! a descriptive [`Error`] instead, so `ModelRuntime::load` fails
//! cleanly at runtime and artifact-dependent tests/benches skip. Swap
//! the real bindings back in via `rust/Cargo.toml` to run the L2/L1
//! graphs (see ROADMAP.md "Open items").

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{}: PJRT is unavailable in this offline build (stub xla crate; \
         swap in the real xla bindings to execute compiled graphs)",
        what
    ))
}

/// Element dtypes the runtime layer names.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U32,
    U8,
    Pred,
}

/// Marker for element types [`Literal::to_vec`] can yield.
pub trait NativeType: Sized {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}
impl NativeType for u32 {}
impl NativeType for u64 {}

/// Host-side literal value (stub: carries no data).
#[derive(Debug, Default)]
pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(unavailable("Literal::create_from_shape_and_untyped_data"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        Err(unavailable("Literal::get_first_element"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

impl From<f32> for Literal {
    fn from(_v: f32) -> Literal {
        Literal
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(unavailable(&format!("loading HLO text {}", path)))
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_errors_are_descriptive() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("PJRT is unavailable"));
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0; 8])
            .is_err());
    }
}
